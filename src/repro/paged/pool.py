"""Host-side page-pool accounting: free list, refcounts, prefix registry.

The device side (:mod:`repro.paged.cache`) holds every quantized cache field
as shared ``(num_pages, H, page_size, ...)`` pool arrays addressed through
per-slot block tables.  THIS module is the bookkeeping that decides which
physical page holds what — it is deliberately pure Python (no jax), because
allocation decisions happen between jitted program launches, once per admit
and at page boundaries during decode (every ``page_size`` steps):

* ``PagePool`` — free-list allocator with per-page refcounts.  A page is
  freed when its refcount drops to zero; shared pages (prefix cache,
  not-yet-diverged clones) simply hold extra references.
* prefix registry — completed prompts register their page list under the
  full token tuple.  A later *identical* prompt re-uses the pages (and the
  stored per-slot statistics) without re-running prefill.  Entries hold one
  reference per page; under allocation pressure the least-recently-used
  entries are evicted, which frees exactly the pages no live slot still
  references (PackKV-style footprint accounting).

  Sharing is keyed on the FULL prompt, not a token prefix: SIKV compression
  statistics (``mu``/``alpha``/centroids, and the sink vote) are computed
  over the whole prompt, so pages holding the same token prefix of two
  different prompts are *not* byte-identical.  Whole-prompt granularity is
  the exact-sharing boundary (see DESIGN.md §3.4).
* ``SlotPageManager`` — per-slot page lists plus the write-path policy:
  before a slot appends at position ``pos`` it must own the covering page
  exclusively, so the manager allocates fresh pages at page boundaries and
  copy-on-writes shared pages on the first divergent append.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs import CounterGroup, get_registry, instance_label


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied even after evicting
    every unreferenced prefix-cache entry."""


@dataclass
class PrefixEntry:
    """One registered prompt: its pages + the per-slot state a future
    identical prompt needs to skip prefill entirely."""

    page_ids: List[int]
    prompt_len: int
    first_token: int
    # per-layer dicts of per-slot cache leaves (batch-1 jax arrays:
    # sink_k/sink_v/res_k/res_v/mu/alpha/centroids) — length-independent,
    # but FULL PRECISION, so for short prompts it can outweigh the
    # compressed pages it caches.  state_bytes makes that cost visible and
    # max_prompts bounds it.
    slot_state: Any
    state_bytes: int = 0
    hits: int = 0


class PagePool:
    """Free-list page allocator with refcounts and an LRU prefix registry."""

    def __init__(self, num_pages: int, page_size: int,
                 max_prompts: int = 32):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError(f"need positive pool dims, got "
                             f"{num_pages=} {page_size=}")
        self.num_pages = num_pages
        self.page_size = page_size
        # cap on registered prompts: each entry pins full-precision
        # slot_state (sinks+ring+stats per layer), which is NOT in the
        # page-bytes budget — bound it instead of letting distinct short
        # prompts accumulate HBM until page pressure finally evicts
        self.max_prompts = max_prompts
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self.refcount: List[int] = [0] * num_pages
        # per-page payload tier (tiered pools only): "device" while the
        # page's payload occupies a staging slot, "host" once demoted,
        # None for free pages / single-tier pools.  Maintained by the
        # tiered serving engine; kept here so allocator snapshots (and
        # PoolExhausted messages) show where every page's payload lives.
        self.tier: List[Optional[str]] = [None] * num_pages
        # observer for freed pages (refcount hit zero): the tiered engine
        # releases the page's staging slot and host copy through this
        self.on_free: Optional[Callable[[List[int]], None]] = None
        # insertion-ordered => oldest entry first; hits re-insert (LRU)
        self.registry: Dict[Tuple[int, ...], PrefixEntry] = {}
        # pages whose refcount includes the registry's own reference
        self._registry_pages: set = set()
        # admission reservations: pages promised to admitted slots that will
        # be drawn lazily during decode.  Without this, admission control
        # could promise the same free page to two slots.  ``reservations``
        # is the per-owner ledger behind the total: SlotPageManager passes
        # its slot index, so snapshots (and the SIKV-I003 balance check)
        # can say WHO holds each promised page, not just how many.
        self.reserved: int = 0
        self.reservations: Dict[Any, int] = {}
        # preemption holds: page lists kept alive on behalf of a preempted
        # (slot-less) request.  Each hold owns one reference per page —
        # exactly like the prefix registry's hold — so spilling a victim
        # can release its slot without the refcount ever reaching zero
        # (which would drop host copies through ``on_free`` in a tiered
        # pool).  Keyed by an opaque owner (the scheduler uses the request
        # uid); the ledger is public so the protocol invariants can count
        # the extra references.
        self.holds: Dict[Any, List[int]] = {}
        # optional per-page annotation hook (tiered engines / the protocol
        # harness set it) consulted by ``page_state``: returns extra detail
        # for a mapped page ("staged-dirty+pinned", "lane", ...) beyond
        # what the pool's own tier map knows
        self.page_detail: Optional[Callable[[int], Optional[str]]] = None
        self.stats: Dict[str, int] = {
            "allocated": 0, "freed": 0, "evictions": 0, "prefix_hits": 0,
        }
        # observability: registry mirror of stats plus allocator gauges
        # (pages in use tracks the free list; refcount keeps a high-water
        # mark of the most-shared page — prefix-sharing pressure)
        reg = get_registry()
        label = instance_label(type(self).__name__)
        self.obs = CounterGroup(self.stats, "pool", pool=label)
        self._g_in_use = reg.gauge("pool.pages_in_use", pool=label)
        self._g_refcount = reg.gauge("pool.refcount", pool=label)

    # -- allocation ----------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def live_refs(self, page: int) -> int:
        """References held by live slots (the prefix registry's own hold is
        excluded — a registered page's beyond-prompt offsets are don't-care,
        so a single live writer may append in place; see SlotPageManager)."""
        return self.refcount[page] - (1 if page in self._registry_pages else 0)

    def reserve(self, n: int, owner: Any = None) -> None:
        self.reserved += n
        if n:
            self.reservations[owner] = self.reservations.get(owner, 0) + n

    def unreserve(self, n: int, owner: Any = None) -> None:
        self.reserved = max(0, self.reserved - n)
        if n and owner in self.reservations:
            left = self.reservations[owner] - n
            if left > 0:
                self.reservations[owner] = left
            else:
                del self.reservations[owner]

    def available(self, protect: Optional[Tuple[int, ...]] = None) -> int:
        """Pages obtainable for a NEW admission: free + freeable by evicting
        registry entries (a registered page frees only if no live slot
        shares it), minus pages already promised to admitted slots."""
        n = len(self._free)
        for key, entry in self.registry.items():
            if key == protect:
                continue
            n += sum(1 for p in entry.page_ids if self.refcount[p] == 1)
        return max(0, n - self.reserved)

    def allocate(self, n: int,
                 protect: Optional[Tuple[int, ...]] = None) -> List[int]:
        """Take ``n`` pages, evicting LRU prefix entries under pressure."""
        while len(self._free) < n and self._evict_one(protect):
            pass
        if len(self._free) < n:
            raise PoolExhausted(
                f"need {n} pages, {len(self._free)} free of "
                f"{self.num_pages} (and nothing left to evict); "
                f"pool snapshot: {self.snapshot(detail=True)}")
        ids = [self._free.pop() for _ in range(n)]
        for p in ids:
            self.refcount[p] = 1
        self.obs.add("allocated", n)
        self._g_in_use.set(self.num_pages - len(self._free))
        return ids

    def share(self, page_ids: Sequence[int]) -> None:
        for p in page_ids:
            assert self.refcount[p] > 0, f"sharing a free page {p}"
            self.refcount[p] += 1
            self._g_refcount.set(self.refcount[p])

    def set_tier(self, page_ids: Sequence[int], tier: Optional[str]) -> None:
        """Record where the pages' payload lives ("device" / "host")."""
        for p in page_ids:
            self.tier[p] = tier

    def tier_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for t in self.tier:
            if t is not None:
                out[t] = out.get(t, 0) + 1
        return out

    # -- preemption holds ----------------------------------------------

    def preempt_hold(self, owner: Any, page_ids: Sequence[int]) -> None:
        """Take one extra reference per page on behalf of a preempted
        request (``owner``).  Must be taken BEFORE the victim's slot is
        released: the hold is what keeps shared pages mapped and — in a
        tiered pool — keeps the refcount above zero so ``on_free`` never
        drops the spilled host copies."""
        assert owner not in self.holds, f"hold already taken for {owner!r}"
        self.share(page_ids)
        self.holds[owner] = list(page_ids)

    def release_hold(self, owner: Any, *, transfer: bool = False) -> List[int]:
        """Drop ``owner``'s preemption hold.  With ``transfer=True`` the
        hold's references are handed to a new owner (a slot binding made
        via ``SlotPageManager.assign``, which does not incref) instead of
        being released — the resume path.  Plain release is the abandon
        path (the request was cancelled while preempted)."""
        pages = self.holds.pop(owner)
        if not transfer:
            self.release(pages)
        return pages

    def held_pages(self) -> Dict[int, int]:
        """Per-page count of preemption-hold references."""
        out: Dict[int, int] = {}
        for pages in self.holds.values():
            for p in pages:
                out[p] = out.get(p, 0) + 1
        return out

    def release(self, page_ids: Sequence[int]) -> None:
        freed: List[int] = []
        for p in page_ids:
            assert self.refcount[p] > 0, f"double free of page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)
                self.tier[p] = None
                self.obs.add("freed")
                freed.append(p)
        self._g_in_use.set(self.num_pages - len(self._free))
        if freed and self.on_free is not None:
            self.on_free(freed)

    # -- prefix registry -----------------------------------------------

    def register_prefix(self, key: Tuple[int, ...], page_ids: Sequence[int],
                        *, prompt_len: int, first_token: int,
                        slot_state: Any, state_bytes: int = 0) -> None:
        if key in self.registry:
            return
        while len(self.registry) >= self.max_prompts \
                and self._evict_one(protect=None):
            pass
        self.share(page_ids)  # the registry's own reference
        self._registry_pages.update(page_ids)
        self.registry[key] = PrefixEntry(
            page_ids=list(page_ids), prompt_len=prompt_len,
            first_token=first_token, slot_state=slot_state,
            state_bytes=state_bytes)

    def lookup_prefix(self, key: Tuple[int, ...]) -> Optional[PrefixEntry]:
        entry = self.registry.get(key)
        if entry is not None:
            self.registry[key] = self.registry.pop(key)  # LRU touch
            entry.hits += 1
            self.obs.add("prefix_hits")
        return entry

    def _evict_one(self, protect: Optional[Tuple[int, ...]]) -> bool:
        for key in self.registry:
            if key != protect:
                entry = self.registry.pop(key)
                self._registry_pages.difference_update(entry.page_ids)
                self.release(entry.page_ids)
                self.obs.add("evictions")
                return True
        return False

    def page_state(self, page: int) -> Optional[str]:
        """Lifecycle label for one page: ``None`` when free, otherwise the
        ``page_detail`` hook's answer (tiered residency: staged-clean,
        staged-dirty+pinned, lane, host-current, reserved...) or the tier
        map / plain "mapped", suffixed with the sharing attributes the pool
        itself knows (``+registry`` hold, ``+sharedN`` for CoW refs)."""
        if self.refcount[page] == 0:
            return None
        held = self.held_pages().get(page, 0)
        slot_refs = (self.refcount[page] - held
                     - (1 if page in self._registry_pages else 0))
        if held and slot_refs == 0:
            # only preemption holds (plus possibly the registry) keep the
            # page alive: no slot maps it, its payload lives on host
            label = "preempted"
        else:
            label = None
            if self.page_detail is not None:
                label = self.page_detail(page)
            if label is None:
                label = self.tier[page] or "mapped"
        if page in self._registry_pages:
            label += "+registry"
        if held:
            label += f"+held{held}"
        live = self.live_refs(page)
        if live > 1:
            label += f"+shared{live}"
        return label

    def snapshot(self, detail: bool = False) -> Dict[str, Any]:
        """Allocator state dump.  Always aggregates per-state page counts
        and the reservation ledger; ``detail=True`` adds the per-page map
        (``PoolExhausted`` and protocol-checker failures print that form,
        so "which page is stuck where" is in the message, not a debugger
        session away)."""
        snap: Dict[str, Any] = dict(
            self.stats, num_pages=self.num_pages,
            free=len(self._free), reserved=self.reserved,
            in_use=self.num_pages - len(self._free),
            registered_prompts=len(self.registry),
            registry_state_bytes=sum(
                e.state_bytes for e in self.registry.values()))
        for tier, n in self.tier_counts().items():
            snap[f"{tier}_payload_pages"] = n
        states: Dict[str, int] = {}
        pages: Dict[int, str] = {}
        for p in range(self.num_pages):
            label = self.page_state(p)
            if label is None:
                continue
            states[label] = states.get(label, 0) + 1
            pages[p] = label
        snap["page_states"] = states
        snap["reservation_ledger"] = dict(self.reservations)
        snap["preempt_holds"] = {repr(k): list(v)
                                 for k, v in self.holds.items()}
        if detail:
            snap["pages"] = pages
        return snap


@dataclass
class _SlotPages:
    pages: List[int] = field(default_factory=list)


class SlotPageManager:
    """Per-slot page lists + the exclusive-write policy over a PagePool.

    The jitted append writes token ``pos`` of slot ``s`` into the pool page
    ``block_table[s, pos // page_size]``.  Before each decode step the
    engine calls :meth:`ensure_writable`; the manager guarantees the
    covering page exists and is writable, issuing the block-table update
    (and the page copy, for copy-on-write un-sharing) through the
    caller-provided device callbacks.

    Copy-on-write triggers when the page has another LIVE sharer
    (``pool.live_refs > 1``).  The prefix registry's own reference is
    exempt: a slot appending at ``pos >= prompt_len`` only writes offsets
    strictly beyond the registered prompt content, and readers never look
    at offsets at or beyond their own length, so a single live writer may
    scribble in place — beyond-prompt offsets of a registered page are
    don't-care bytes.  This saves one page copy per admission.

    Admission *reservations*: each slot may carry a budget of pages it was
    promised at admit time; lazy decode allocations draw that budget down
    (``pool.reserved`` global counter), so admission control can never
    promise the same free page twice.

    Callbacks (kept abstract so a single-cache test and the multi-layer
    engine share this logic):

    * ``set_block(slot, j, page_id)`` — write one block-table entry;
    * ``copy_page(src, dst)`` — copy a pool page across every layer.
    """

    def __init__(self, pool: PagePool, pages_per_seq: int, num_slots: int,
                 *, set_block: Callable[[int, int, int], None],
                 copy_page: Callable[[int, int], None],
                 on_alloc: Optional[Callable[[int, int], None]] = None):
        self.pool = pool
        self.pages_per_seq = pages_per_seq
        self._slots: List[Optional[_SlotPages]] = [None] * num_slots
        self._resv: List[int] = [0] * num_slots
        self._set_block = set_block
        self._copy_page = copy_page
        # notified with (slot, page) for every page allocated fresh during
        # decode (boundary appends and copy-on-write targets): the tiered
        # engine binds a staging slot to the new write page here — fresh
        # pages have no host copy to fetch, so this is the one lifecycle
        # point that distinguishes them from re-opened host-tier pages
        self.on_alloc = on_alloc
        self.cow_copies = 0
        self._m_cow = get_registry().counter("pool.cow_copies",
                                             pool=pool.obs.labels["pool"])

    def slot_pages(self, slot: int) -> Optional[List[int]]:
        s = self._slots[slot]
        return None if s is None else list(s.pages)

    def assign(self, slot: int, page_ids: Sequence[int],
               *, reserved: int = 0) -> None:
        """Bind an allocated/shared page list to a slot (admission),
        optionally reserving ``reserved`` future pages for its decode.

        Host-side bookkeeping only: the admission insert
        (``insert_prefill_pages`` / ``insert_slot_state``) writes the whole
        device block-table row in the same launch as the cache data, so
        issuing ``pages_per_seq`` individual ``set_block`` updates here
        would be dead work on the TTFT path.  ``set_block`` is reserved for
        the incremental updates of :meth:`ensure_writable`."""
        self.release_slot(slot)
        self._slots[slot] = _SlotPages(list(page_ids))
        self._resv[slot] = reserved
        self.pool.reserve(reserved, owner=slot)

    def release_slot(self, slot: int) -> None:
        s = self._slots[slot]
        if s is not None:
            self.pool.release(s.pages)
            self._slots[slot] = None
        self.pool.unreserve(self._resv[slot], owner=slot)
        self._resv[slot] = 0

    def _take_page(self, slot: int) -> int:
        pid = self.pool.allocate(1)[0]
        if self._resv[slot] > 0:
            self._resv[slot] -= 1
            self.pool.unreserve(1, owner=slot)
        if self.on_alloc is not None:
            self.on_alloc(slot, pid)
        return pid

    def truncate(self, slot: int, n_keep: int) -> List[int]:
        """Release the slot's pages beyond its first ``n_keep`` (rollback of
        a rejected speculation tail).  Each released page's block-table
        entry is unmapped FIRST, so the dead mapping can never absorb a
        write after the page is re-allocated, and the release is re-added
        to the slot's admission reservation BEFORE the pool sees the free
        page — the slot will draw the page again at its next boundary, and
        without the re-credit ``pool.available`` could promise it to a
        competing admission in between (the reservation invariant:
        ``reserved`` always covers the slot's remaining worst-case draws).

        Returns the released page ids (refcount 1 by construction — decode
        tail pages are never shared; freeing triggers ``pool.on_free``, so
        a tiered store drops their staged/host payload and force-clears a
        stale prefetch lane through the existing observer chain)."""
        s = self._slots[slot]
        if s is None or n_keep >= len(s.pages):
            return []
        released = s.pages[n_keep:]
        del s.pages[n_keep:]
        for j in range(n_keep, n_keep + len(released)):
            self._set_block(slot, j, -1)
        self._resv[slot] += len(released)
        self.pool.reserve(len(released), owner=slot)
        self.pool.release(released)
        return released

    def ensure_writable(self, slot: int, pos: int) -> None:
        """Make ``pos`` of ``slot`` appendable: allocate at page boundaries,
        copy-on-write pages with another live sharer on first divergence."""
        s = self._slots[slot]
        if s is None or pos >= self.pages_per_seq * self.pool.page_size:
            return  # dead slot / past capacity: the jitted write no-ops
        j = pos // self.pool.page_size
        if j == len(s.pages):
            pid = self._take_page(slot)
            s.pages.append(pid)
            self._set_block(slot, j, pid)
        elif j < len(s.pages) and self.pool.live_refs(s.pages[j]) > 1:
            new = self._take_page(slot)
            self._copy_page(s.pages[j], new)
            self.pool.release([s.pages[j]])
            s.pages[j] = new
            self._set_block(slot, j, new)
            self.cow_copies += 1
            self._m_cow.inc()

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

"""Paged compressed-KV pool: block-table memory management for serving.

Device side (:mod:`repro.paged.cache`, :mod:`repro.paged.attention`):
pooled ``(num_pages, H, page_size, ...)`` arrays for every quantized cache
field, per-slot block tables, and a decode attention that is bit-exact
against the dense :class:`~repro.core.cache.SIKVCache` path.

Host side (:mod:`repro.paged.pool`): free-list allocation, refcounts,
copy-on-write, and whole-prompt prefix caching.

Serving integration lives in :class:`repro.serving.PagedServingEngine`.
"""
from repro.paged.attention import paged_sikv_decode_attention
from repro.paged.cache import (PagedSIKVCache, append_token_paged,
                               copy_pool_page, init_paged_cache,
                               insert_prefill_pages, insert_slot_state,
                               paged_gather_dequant, paged_token_bytes,
                               set_block_entry, tree_copy_page,
                               tree_set_block_entry)
from repro.paged.pool import (PagePool, PoolExhausted, PrefixEntry,
                              SlotPageManager)

__all__ = [
    "PagedSIKVCache", "PagePool", "PoolExhausted", "PrefixEntry",
    "SlotPageManager", "append_token_paged", "copy_pool_page",
    "init_paged_cache", "insert_prefill_pages", "insert_slot_state",
    "paged_gather_dequant", "paged_sikv_decode_attention",
    "paged_token_bytes", "set_block_entry", "tree_copy_page",
    "tree_set_block_entry",
]

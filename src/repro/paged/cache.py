"""Paged Self-Indexing KV cache: pooled pages + per-slot block tables.

The dense :class:`~repro.core.cache.SIKVCache` allocates ``(B, H, Lmax, ...)``
per slot, so a 512-token request reserves (and a serving batch pays for) the
worst-case context.  Here every *token-indexed* field — ``codes``, ``kmag``,
``k_scale``/``k_zp``, ``v_q``, ``v_scale``/``v_zp`` and the per-token
``sink_mask`` metadata — lives once, in shared ``(num_pages, H, page_size,
...)`` pool arrays, and each serving slot owns only a ``(pages_per_seq,)``
row of the block table mapping its logical pages to physical ones.  The
*per-sequence* state (full-precision sinks, the recent ring, and the reused
prefill statistics ``mu``/``alpha``/centroids) stays per-slot — it does not
grow with length and cannot be shared across different prompts.

Everything here is functional jax (jits/shards like the dense cache); WHICH
page a slot owns is decided host-side by :mod:`repro.paged.pool`.

Layout choice: one page spans all KV heads of ``page_size`` consecutive
tokens of one sequence — the same page granularity for every layer, so one
host-side allocation covers a token range in all layers at once (vLLM-style
shared block tables, see DESIGN.md §3).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import SIKVConfig
from repro.core.cache import (SIKVCache, batched_update_token,
                              dequantize_gathered, quantize_decode_token)
from repro.core.retrieval import gather_selected_paged

__all__ = [
    "PagedSIKVCache", "init_paged_cache", "insert_prefill_pages",
    "insert_slot_state", "append_token_paged", "paged_gather_dequant",
    "copy_pool_page", "set_block_entry", "clear_slot_row",
    "tree_copy_page", "tree_set_block_entry", "tree_clear_slot_row",
    "paged_token_bytes", "PER_SLOT_FIELDS", "TOKEN_FIELDS",
]

# pool-resident, token-indexed fields (page-major layout)
TOKEN_FIELDS = ("codes", "kmag", "k_scale", "k_zp", "v_q", "v_scale",
                "v_zp", "sink_mask")
# per-slot fields that never grow with sequence length
PER_SLOT_FIELDS = ("sink_k", "sink_v", "res_k", "res_v", "mu", "alpha",
                   "centroids")


class PagedSIKVCache(NamedTuple):
    # ---- shared pool, page-major: (P, H, page_size, ...) ----
    codes: jax.Array       # (P, H, ps, G)             int8
    kmag: jax.Array        # (P, H, ps, D*kbits//8)    int8 (packed)
    k_scale: jax.Array     # (P, H, ps, D//qg)
    k_zp: jax.Array        # (P, H, ps, D//qg)
    v_q: jax.Array         # (P, H, ps, vw)            int8 (packed)
    v_scale: jax.Array     # (P, H, ps, vs)
    v_zp: jax.Array        # (P, H, ps, vs)
    sink_mask: jax.Array   # (P, H, ps)                bool
    # ---- per-slot ----
    block_table: jax.Array  # (B, pages_per_seq)       int32, -1 = unmapped
    sink_k: jax.Array      # (B, H, S, D)
    sink_v: jax.Array      # (B, H, S, Dv)
    res_k: jax.Array       # (B, H, R, D)
    res_v: jax.Array       # (B, H, R, Dv)
    mu: jax.Array          # (B, H, 1, D)
    alpha: jax.Array       # (B, H, 1, D)
    centroids: jax.Array   # (B, H, G, C, gs)
    length: jax.Array      # (B,)                      int32

    @property
    def num_pages(self) -> int:
        return self.codes.shape[0]

    @property
    def page_size(self) -> int:
        return self.codes.shape[2]

    @property
    def pages_per_seq(self) -> int:
        return self.block_table.shape[1]

    @property
    def capacity(self) -> int:
        """Logical per-slot capacity (== the dense cache's ``Lmax``)."""
        return self.pages_per_seq * self.page_size

    @property
    def head_dim(self) -> int:
        return self.mu.shape[-1]

    @property
    def num_sinks(self) -> int:
        return self.sink_k.shape[2]

    @property
    def recent_window(self) -> int:
        return self.res_k.shape[2]


def init_paged_cache(dense: SIKVCache, num_pages: int, page_size: int,
                     num_slots: int) -> PagedSIKVCache:
    """Build an empty paged cache shaped after a dense template.

    ``dense`` (any batch) supplies the field dtypes and trailing dims, so
    the pool works for every configuration the dense cache supports (GQA,
    MLA latent keys, ``value_slice``).  ``dense.capacity`` must be a
    page-size multiple — it becomes the logical per-slot capacity.
    """
    if dense.capacity % page_size:
        raise ValueError(
            f"dense capacity {dense.capacity} not divisible by "
            f"page_size {page_size}")
    pages_per_seq = dense.capacity // page_size
    pool = {
        f: jnp.zeros((num_pages,) + (getattr(dense, f).shape[1],)
                     + (page_size,) + getattr(dense, f).shape[3:],
                     getattr(dense, f).dtype)
        for f in TOKEN_FIELDS
    }
    slot = {
        f: jnp.zeros((num_slots,) + getattr(dense, f).shape[1:],
                     getattr(dense, f).dtype)
        for f in PER_SLOT_FIELDS
    }
    return PagedSIKVCache(
        block_table=jnp.full((num_slots, pages_per_seq), -1, jnp.int32),
        length=jnp.zeros((num_slots,), jnp.int32),
        **pool, **slot)


def _paged_view(src: jax.Array, pages_per_seq: int,
                page_size: int) -> jax.Array:
    """``(H, L, ...) -> (npages, H, ps, ...)`` page-major reshape."""
    s = src.reshape(src.shape[0], pages_per_seq, page_size, *src.shape[2:])
    return jnp.moveaxis(s, 1, 0)


def insert_prefill_pages(paged: PagedSIKVCache, dense: SIKVCache,
                         slot: jax.Array,
                         page_ids: jax.Array) -> PagedSIKVCache:
    """Scatter a batch-1 dense prefill cache into the pool + slot row.

    Args:
      dense: batch-1 cache with ``capacity == paged.capacity``.
      page_ids: ``(pages_per_seq,)`` int32 — physical page per logical page;
        ``-1`` entries (pages beyond the prompt, allocated lazily during
        decode) are dropped by the scatter's out-of-bounds mode.

    With chunked admission (DESIGN.md §4.3) this scatter runs only at the
    FINAL chunk, but ``page_ids`` were allocated at ``admit_start`` — the
    prompt's pages and its worst-case decode-tail reservation are held for
    the whole admission window, so the decode steps interleaved between
    chunks can never draw down pages the staged prompt still needs.
    """
    P = paged.num_pages
    ids = jnp.where(page_ids >= 0, page_ids, P)  # OOB => dropped
    upd: dict[str, jax.Array] = {}
    for f in TOKEN_FIELDS:
        buf = getattr(paged, f)
        src = _paged_view(getattr(dense, f)[0], paged.pages_per_seq,
                          paged.page_size)
        upd[f] = buf.at[ids].set(src.astype(buf.dtype))
    for f in PER_SLOT_FIELDS:
        buf = getattr(paged, f)
        upd[f] = buf.at[slot].set(getattr(dense, f)[0].astype(buf.dtype))
    upd["block_table"] = paged.block_table.at[slot].set(page_ids)
    upd["length"] = paged.length.at[slot].set(dense.length[0])
    return paged._replace(**upd)


def insert_slot_state(paged: PagedSIKVCache, slot_state: dict,
                      slot: jax.Array, page_ids: jax.Array,
                      length: jax.Array) -> PagedSIKVCache:
    """Admit a prefix-cache hit: bind shared pages + the stored per-slot
    statistics to ``slot`` without touching the pool (no prefill ran)."""
    upd = {
        f: getattr(paged, f).at[slot].set(
            slot_state[f][0].astype(getattr(paged, f).dtype))
        for f in PER_SLOT_FIELDS
    }
    upd["block_table"] = paged.block_table.at[slot].set(page_ids)
    upd["length"] = paged.length.at[slot].set(length)
    return paged._replace(**upd)


def append_token_paged(paged: PagedSIKVCache, k_new: jax.Array,
                       v_new: jax.Array, cfg: SIKVConfig) -> PagedSIKVCache:
    """Append one decode token per slot through the block table.

    Quantization goes through the exact dense code path
    (:func:`~repro.core.cache.quantize_decode_token`), then scatters into
    ``pool[block_table[b, pos // ps], :, pos % ps]``.  Guards mirror the
    dense range guard: positions past capacity, or whose page is unmapped,
    write nothing (dead serving slots stay memory-safe).  The appended
    slot's ``sink_mask`` is cleared explicitly — a freshly (re)allocated
    page may hold stale metadata from a previous sequence, where the dense
    cache could rely on its zero-initialized rows.
    """
    codes, kq, vq, v_ring = quantize_decode_token(
        k_new, v_new, paged.mu, paged.alpha, cfg)

    ps, P = paged.page_size, paged.num_pages
    pos = paged.length                                        # (B,)
    page_l = jnp.clip(pos // ps, 0, paged.pages_per_seq - 1)
    pg = jnp.take_along_axis(paged.block_table, page_l[:, None], axis=1)[:, 0]
    ok = (pos >= 0) & (pos < paged.capacity) & (pg >= 0)
    pg = jnp.where(ok, pg, P)                                 # OOB => dropped
    off = pos % ps

    def upd(buf, val):  # val (B, H, 1, X) -> write (B, H, X) rows
        return buf.at[pg, :, off].set(val[:, :, 0].astype(buf.dtype))

    R = paged.recent_window
    return paged._replace(
        codes=upd(paged.codes, codes),
        kmag=upd(paged.kmag, kq.packed),
        k_scale=upd(paged.k_scale, kq.scale),
        k_zp=upd(paged.k_zp, kq.zp),
        v_q=upd(paged.v_q, vq.packed),
        v_scale=upd(paged.v_scale, vq.scale),
        v_zp=upd(paged.v_zp, vq.zp),
        sink_mask=paged.sink_mask.at[pg, :, off].set(False),
        res_k=batched_update_token(paged.res_k, k_new, pos % R),
        res_v=batched_update_token(paged.res_v, v_ring, pos % R),
        length=paged.length + 1,
    )


def paged_gather_dequant(paged: PagedSIKVCache, idx: jax.Array,
                         cfg: SIKVConfig) -> tuple[jax.Array, jax.Array]:
    """Gather + dequantize selected logical positions ``idx (B, H, T)``.

    The token-wise physical gather routes through the block table; the
    dequantization is the dense
    :func:`~repro.core.cache.dequantize_gathered` verbatim.
    """
    take = lambda f: gather_selected_paged(getattr(paged, f),
                                           paged.block_table, idx,
                                           paged.page_size)
    return dequantize_gathered(
        take("codes"), take("kmag"), take("k_scale"), take("k_zp"),
        take("v_q"), take("v_scale"), take("v_zp"),
        paged.mu, paged.alpha, cfg)


def copy_pool_page(paged: PagedSIKVCache, src: jax.Array,
                   dst: jax.Array) -> PagedSIKVCache:
    """Copy one physical page (all token fields) — the copy-on-write step."""
    return paged._replace(**{
        f: getattr(paged, f).at[dst].set(getattr(paged, f)[src])
        for f in TOKEN_FIELDS
    })


def set_block_entry(paged: PagedSIKVCache, slot: jax.Array, j: jax.Array,
                    page_id: jax.Array) -> PagedSIKVCache:
    return paged._replace(
        block_table=paged.block_table.at[slot, j].set(page_id))


def clear_slot_row(paged: PagedSIKVCache, slot: jax.Array) -> PagedSIKVCache:
    """Unmap a retired slot's block-table row.  Unlike the dense engine,
    where a dead row harmlessly absorbs writes until its length passes
    capacity, a paged slot's row points at pages that retire() RELEASED —
    the next admission may re-allocate them, so the dead slot's appends
    must be cut off at the mapping (``page == -1`` drops the write)."""
    return paged._replace(
        block_table=paged.block_table.at[slot].set(-1))


def is_block_mapped_cache(x: Any) -> bool:
    """Any pool cache addressed through a per-slot block table — the
    single-tier :class:`PagedSIKVCache` or the tiered
    :class:`~repro.tiered.cache.TieredSIKVCache` (duck-typed to avoid a
    paged -> tiered import cycle).  The block-table ops and the per-slot
    state insert are layout-agnostic over both."""
    return isinstance(x, PagedSIKVCache) or (
        hasattr(x, "block_table") and hasattr(x, "payload_map"))


def _map_paged(fn, tree: Any) -> Any:
    """Apply ``fn`` to every PagedSIKVCache inside a caches pytree."""
    return jax.tree_util.tree_map(
        lambda c: fn(c) if isinstance(c, PagedSIKVCache) else c,
        tree, is_leaf=lambda x: isinstance(x, PagedSIKVCache))


def _map_block_mapped(fn, tree: Any) -> Any:
    """Apply ``fn`` to every block-mapped cache (paged OR tiered)."""
    return jax.tree_util.tree_map(
        lambda c: fn(c) if is_block_mapped_cache(c) else c,
        tree, is_leaf=is_block_mapped_cache)


def tree_copy_page(caches: Any, src: jax.Array, dst: jax.Array) -> Any:
    """Copy-on-write one page id across every layer's paged cache (paged
    only: the tiered CoW must route the payload half through its staging
    pool — :class:`repro.serving.tiered_engine.TieredServingEngine`)."""
    return _map_paged(lambda c: copy_pool_page(c, src, dst), caches)


def tree_set_block_entry(caches: Any, slot: jax.Array, j: jax.Array,
                         page_id: jax.Array) -> Any:
    """Update one block-table entry across every layer's cache."""
    return _map_block_mapped(
        lambda c: set_block_entry(c, slot, j, page_id), caches)


def tree_clear_slot_row(caches: Any, slot: jax.Array) -> Any:
    """Unmap a slot's block-table row across every layer's cache."""
    return _map_block_mapped(lambda c: clear_slot_row(c, slot), caches)


def paged_token_bytes(paged: PagedSIKVCache) -> int:
    """HBM bytes of the pooled token store (block table included)."""
    n = paged.block_table.nbytes
    for f in TOKEN_FIELDS:
        n += getattr(paged, f).nbytes
    return n

"""Figure 4 / Table 2 proxy (Ruler 32K): accuracy vs sparsity ratio.

Sweeps the kept-token ratio and reports attention-output fidelity per
method.  The paper's claim: SIKV holds accuracy down to 7.5 % sparsity where
baselines degrade.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header
from repro.config import SIKVConfig
from repro.core.attention import full_causal_attention, group_queries
from repro.data.synthetic import structured_kv
from repro.sparse import get_method

METHODS = ["sikv", "snapkv", "quest", "double_sparse"]
RATIOS = [0.025, 0.05, 0.075, 0.15, 0.5]


def run(L: int = 4096) -> None:
    header("bench_ruler_proxy (paper Fig. 4 / Table 2, ratio sweep)")
    B, Hq, Hkv, D = 1, 8, 4, 64
    key = jax.random.PRNGKey(0)
    k, v = structured_kv(key, B, Hkv, L, D)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[1], (B, Hq, 1, D))
    q_kv = group_queries(q[:, :, 0, :], Hkv)
    q_obs = q_kv[:, :, None, :] + 2.0 * jax.random.normal(
        ks[0], (B, Hkv, 32, D))
    k_new = jax.random.normal(ks[2], (B, Hkv, 1, D)) * 0.1
    v_new = jax.random.normal(ks[3], (B, Hkv, 1, D)) * 0.1
    ref = full_causal_attention(
        q, jnp.concatenate([k, k_new], 2), jnp.concatenate([v, v_new], 2),
        q_offset=L)
    import dataclasses
    for ratio in RATIOS:
        budget = max(96, int(ratio * L))
        cfg = SIKVConfig(num_sink_tokens=min(64, budget // 2),
                         token_budget=budget, recent_window=16,
                         obs_window=32)
        row = []
        audit = ""
        for m in METHODS:
            meth = get_method(m, cfg)
            cache = meth.prefill(k, v, q_obs, capacity=L + 8)
            out, _ = meth.decode(q, k_new, v_new, cache)
            mse = float(jnp.mean((out - ref) ** 2))
            row.append((m, mse))
            if m == "sikv":
                # shared definition with the online audit plane
                # (DESIGN.md §10): sign-code top-k recall and softmax
                # mass coverage at this sparsity ratio
                from repro.core.attention import sikv_static_audit_metrics
                am = sikv_static_audit_metrics(q, cache, cfg)
                audit = (f";sikv_recall={float(jnp.mean(am['recall'])):.3f}"
                         f";sikv_coverage="
                         f"{float(jnp.mean(am['coverage'])):.3f}")
        # paper's "Ours (16 bits)" row: 1-bit index, (near-)full-precision
        # payload — isolates selection quality from quantization error
        cfg16 = dataclasses.replace(cfg, key_bits=8, value_bits=8)
        meth = get_method("sikv", cfg16)
        cache = meth.prefill(k, v, q_obs, capacity=L + 8)
        out, _ = meth.decode(q, k_new, v_new, cache)
        row.append(("sikv16", float(jnp.mean((out - ref) ** 2))))
        derived = ";".join(f"{m}={mse:.5f}" for m, mse in row) + audit
        emit(f"ruler_proxy/ratio={ratio}", 0.0, derived)

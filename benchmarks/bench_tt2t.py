"""Table 3 proxy (TT2T): prefill overhead of cache compression.

The paper's claim: one-pass compression adds ~5 % to Time-To-2nd-Token over
plain FlashAttention prefill.  We time full-model prefill WITH cache
construction vs the bare forward pass at several prompt lengths (CPU,
reduced model — the ratio is the claim under test, not absolute seconds).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header, time_fn
from repro.config import SIKVConfig, get_model_config, reduced_config
from repro.models import forward_train, init_params, prefill
from repro.sparse import get_method


def run() -> None:
    header("bench_tt2t (paper Table 3, prefill overhead)")
    import dataclasses
    cfg = reduced_config(get_model_config("llama3.1-8b"), num_layers=2,
                         d_model=256)
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    sikv = SIKVConfig(num_sink_tokens=64, token_budget=160,
                      recent_window=16, obs_window=32)
    for L in [512, 1024, 2048]:
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, L), 0,
                                  cfg.vocab_size)
        bare = jax.jit(lambda p, t: forward_train(p, cfg, {"tokens": t})[0])
        t_bare = time_fn(bare, params, toks, iters=3)
        m = get_method("sikv", sikv)
        pre = jax.jit(functools.partial(prefill, cfg=cfg, method=m,
                                        capacity=L + 16))
        t_pre = time_fn(lambda p, t: pre(p, batch={"tokens": t})[0],
                        params, toks, iters=3)
        emit(f"tt2t/L={L}", t_pre,
             f"bare={t_bare:.0f}us;overhead={100 * (t_pre / t_bare - 1):.1f}%")
        # ragged (right-padded) prefill: per-sequence lengths thread pad
        # masks through the compression stats — overhead should be ~free
        lens = jnp.asarray([L // 2], jnp.int32)
        t_rag = time_fn(
            lambda p, t: pre(p, batch={"tokens": t, "lengths": lens})[0],
            params, toks, iters=3)
        emit(f"tt2t_ragged/L={L}", t_rag,
             f"dense={t_pre:.0f}us;overhead={100 * (t_rag / t_pre - 1):.1f}%")

"""Serving throughput under mixed-length traffic: continuous batching vs
lock-step batching.

The workload mixes >= 3 distinct prompt lengths and heterogeneous
``max_new_tokens`` — the regime the paper targets (memory-efficient
large-batch inference) and the one lock-step batching handles worst: every
batch runs to its *longest* member while finished slots idle.  The slot
scheduler retires finished requests mid-decode and refills the slot from
the queue without recompiling, so it launches strictly fewer engine
programs.

Emits, per policy: engine invocations (prefills + decode steps — the
apples-to-apples work metric), wall time, aggregate token throughput, and
mean TTFT/TPOT.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit, header
from repro.config import SIKVConfig, get_model_config, reduced_config
from repro.data.synthetic import lm_sequence_batch
from repro.models import init_params
from repro.serving import Request, RequestScheduler, ServingEngine


def _mixed_requests(cfg, n: int, prompt_len: int):
    """>= 3 distinct prompt lengths, differing max_new_tokens."""
    toks = lm_sequence_batch(jax.random.PRNGKey(7), n, prompt_len,
                             cfg.vocab_size)
    plens = [prompt_len, prompt_len // 2, prompt_len // 4]
    news = [4, 8, 16]
    return [
        Request(uid=i,
                prompt=[int(t) for t in toks[i, : plens[i % len(plens)]]],
                max_new_tokens=news[i % len(news)])
        for i in range(n)
    ]


def _make_engine(params, cfg, sikv, batch, prompt_len):
    return ServingEngine(params, cfg, sikv, method="sikv", batch_size=batch,
                         prompt_len=prompt_len,
                         max_new_tokens=max(16, prompt_len // 4))


def run(*, batch: int = 2, prompt_len: int = 64, n_requests: int = 6,
        arch: str = "llama3.1-8b"):
    header("bench_serving (continuous vs lock-step batching)")
    import dataclasses
    cfg = reduced_config(get_model_config(arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    sikv = SIKVConfig(num_sink_tokens=8, token_budget=28, recent_window=4,
                      obs_window=8)
    params = init_params(jax.random.PRNGKey(0), cfg)

    results = {}
    for policy in ["lockstep", "continuous"]:
        eng = _make_engine(params, cfg, sikv, batch, prompt_len)
        sched = RequestScheduler(eng)
        for r in _mixed_requests(cfg, n_requests, prompt_len):
            sched.submit(Request(uid=r.uid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens))
        t0 = time.time()
        done = (sched.flush_lockstep() if policy == "lockstep"
                else sched.run())
        dt = time.time() - t0
        toks = sum(len(r.result) for r in sched.completed.values())
        stats = sched.service_stats()
        inv = eng.invocations()
        results[policy] = inv
        emit(f"serving/{policy}", dt * 1e6,
             f"requests={done};tokens={toks};invocations={inv};"
             f"prefills={eng.stats['prefills']};steps={eng.stats['steps']};"
             f"tok_per_s={toks / dt:.1f};ttft_ms={stats['ttft_mean'] * 1e3:.1f};"
             f"tpot_ms={stats['tpot_mean'] * 1e3:.1f}")

    saved = results["lockstep"] - results["continuous"]
    emit("serving/invocations_saved", 0.0,
         f"lockstep={results['lockstep']};continuous={results['continuous']};"
         f"saved={saved}")
    assert results["continuous"] < results["lockstep"], results
    return results


if __name__ == "__main__":
    run()

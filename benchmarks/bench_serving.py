"""Serving throughput under mixed-length traffic: continuous batching vs
lock-step batching, and paged-pool vs dense-slot concurrency.

The workload mixes >= 3 distinct prompt lengths and heterogeneous
``max_new_tokens`` — the regime the paper targets (memory-efficient
large-batch inference) and the one lock-step batching handles worst: every
batch runs to its *longest* member while finished slots idle.  The slot
scheduler retires finished requests mid-decode and refills the slot from
the queue without recompiling, so it launches strictly fewer engine
programs.

Emits, per policy: engine invocations (prefills + decode steps — the
apples-to-apples work metric), wall time, aggregate token throughput, and
mean TTFT/TPOT.

The paged section fixes a token-store HBM budget (what a dense engine with
``dense_slots`` slots allocates), gives the paged engine the SAME budget in
pool pages, runs a mixed-length workload with repeated prompts, and reports
the peak number of simultaneously-active sequences each layout sustains,
plus per-request prefix-cache hits.

The chunked-admission section measures head-of-line blocking: a live
request decodes while a long prompt admits mid-stream.  Monolithic
admission freezes the live slot for the whole prefill; chunked admission
(``prefill_chunk``) interleaves one chunk per decode step (merged into a
single launch), so the live slot's worst inter-token gap
(``max_decode_stall``) collapses while the long request's TTFT stays
within a few percent.  Emits the per-step token budget
(``policy.step_token_budget``) next to the realized ``max_step_tokens``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import assert_ratio, emit, header
from repro import obs
from repro.config import SIKVConfig, get_model_config, reduced_config
from repro.core.cache import init_cache
from repro.core.policy import staging_pages_needed, tiered_pool_split
from repro.data.synthetic import lm_sequence_batch
from repro.models import init_params
from repro.obs import percentiles
from repro.sched import SLOScheduler
from repro.serving import (PagedServingEngine, Request, RequestScheduler,
                           ServingEngine, TieredServingEngine)
from repro.tiered.cache import page_byte_split


def _mixed_requests(cfg, n: int, prompt_len: int):
    """>= 3 distinct prompt lengths, differing max_new_tokens."""
    toks = lm_sequence_batch(jax.random.PRNGKey(7), n, prompt_len,
                             cfg.vocab_size)
    plens = [prompt_len, prompt_len // 2, prompt_len // 4]
    news = [4, 8, 16]
    return [
        Request(uid=i,
                prompt=[int(t) for t in toks[i, : plens[i % len(plens)]]],
                max_new_tokens=news[i % len(news)])
        for i in range(n)
    ]


def _make_engine(params, cfg, sikv, batch, prompt_len):
    return ServingEngine(params, cfg, sikv, method="sikv", batch_size=batch,
                         prompt_len=prompt_len,
                         max_new_tokens=max(16, prompt_len // 4))


def run(*, batch: int = 2, prompt_len: int = 64, n_requests: int = 6,
        arch: str = "llama3.1-8b", smoke: bool = False):
    header("bench_serving (continuous vs lock-step batching)")
    # the sections below read their launch/transfer counters from the
    # metrics registry (engines mirror their stats dicts into it), so the
    # registry must be live before any engine is constructed
    obs.set_enabled(True)
    import dataclasses
    cfg = reduced_config(get_model_config(arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    sikv = SIKVConfig(num_sink_tokens=8, token_budget=28, recent_window=4,
                      obs_window=8)
    params = init_params(jax.random.PRNGKey(0), cfg)

    results = {}
    for policy in ["lockstep", "continuous"]:
        eng = _make_engine(params, cfg, sikv, batch, prompt_len)
        sched = RequestScheduler(eng)
        for r in _mixed_requests(cfg, n_requests, prompt_len):
            sched.submit(Request(uid=r.uid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens))
        t0 = time.time()
        done = (sched.flush_lockstep() if policy == "lockstep"
                else sched.run())
        dt = time.time() - t0
        toks = sum(len(r.result) for r in sched.completed.values())
        stats = sched.service_stats()
        inv = eng.invocations()
        results[policy] = inv
        # launch counts come from the metrics registry (per-engine labeled
        # series), not the engine's stats dict — same integers, but this
        # exercises the export path every consumer uses
        reg = obs.get_registry()
        prefills = reg.value("engine.prefills", engine=eng.obs_label)
        steps = reg.value("engine.steps", engine=eng.obs_label)
        assert prefills == eng.stats["prefills"], (prefills, eng.stats)
        assert steps == eng.stats["steps"], (steps, eng.stats)
        emit(f"serving/{policy}", dt * 1e6,
             f"requests={done};tokens={toks};invocations={inv};"
             f"prefills={prefills};steps={steps};"
             f"tok_per_s={toks / dt:.1f};ttft_ms={stats['ttft_mean'] * 1e3:.1f};"
             f"tpot_ms={stats['tpot_mean'] * 1e3:.1f};"
             f"n_requests={stats['n_requests']};"
             f"n_decoded={stats['n_decoded']};"
             f"ttft_p95_ms={stats['ttft_p95'] * 1e3:.1f};"
             f"tpot_p95_ms={stats['tpot_p95'] * 1e3:.2f}")

    saved = results["lockstep"] - results["continuous"]
    emit("serving/invocations_saved", 0.0,
         f"lockstep={results['lockstep']};continuous={results['continuous']};"
         f"saved={saved}")
    assert results["continuous"] < results["lockstep"], results

    results["paged"] = paged_concurrency(params, cfg, sikv,
                                         prompt_len=prompt_len, smoke=smoke)
    if smoke:
        results["tiered"] = tiered_concurrency(
            params, cfg, sikv, prompt_len=32, page_size=4, max_new=8,
            n_requests=6, ratio_floor=1.0, smoke=True)
        results["prefetch"] = tiered_prefetch_sweep(
            params, cfg, sikv, prompt_len=32, page_size=4, max_new=8,
            depths=(0, 2))
        # exercise the chunked-admission path + emit the stall metrics at
        # CI-friendly shapes; at toy sizes launch overhead dominates the
        # stall, so the 4x/10% acceptance bars only apply to the full run
        results["stall"] = chunked_admission_stall(
            arch, prompt_len=64, chunk=16, d_model=256, num_layers=2,
            live_new=8, smoke=True)
        results["spec"] = spec_decode_section(
            arch, prompt_len=32, max_new=12, n_requests=4, train_steps=60,
            smoke=True)
        results["sched"] = sched_slo_section(
            params, cfg, sikv, prompt_len=32, page_size=8, max_new=10,
            n_batch=4, n_interactive=3, smoke=True)
    else:
        results["tiered"] = tiered_concurrency(params, cfg, sikv)
        results["prefetch"] = tiered_prefetch_sweep(params, cfg, sikv)
        results["stall"] = chunked_admission_stall(arch)
        results["spec"] = spec_decode_section(arch)
        results["sched"] = sched_slo_section(params, cfg, sikv,
                                             prompt_len=prompt_len)
    return results


def _repeat_prompts(cfg, prompt_len: int, repeats: int = 3):
    """3 distinct prompt lengths, each prompt text repeated ``repeats``
    times (identical repeats => prefix-cache hits)."""
    toks = lm_sequence_batch(jax.random.PRNGKey(21), 3, prompt_len,
                             cfg.vocab_size)
    plens = [prompt_len, prompt_len // 2, prompt_len // 4]
    base = [[int(t) for t in toks[i, : plens[i]]] for i in range(3)]
    reqs = []
    for i, p in enumerate(base):
        for r in range(repeats):
            reqs.append(Request(uid=len(reqs), prompt=list(p),
                                max_new_tokens=4))
    return reqs


def paged_concurrency(params, cfg, sikv, *, prompt_len: int = 64,
                      page_size: int = 16, dense_slots: int = 2,
                      smoke: bool = False):
    """Max concurrent sequences under a FIXED token-store budget.

    The budget is what ``dense_slots`` dense slots allocate; the paged
    engine gets the identical number of page-bytes
    (``dense_slots * pages_per_seq`` pages) and serves the same workload.
    Page admission + prefix sharing let it run strictly more sequences at
    once; the acceptance bar is >= 2x.
    """
    header("bench_serving: paged pool vs dense slots @ fixed HBM budget")
    max_new = max(16, prompt_len // 4)

    # dense baseline: concurrency == the slots the budget buys
    eng_d = ServingEngine(params, cfg, sikv, method="sikv",
                          batch_size=dense_slots, prompt_len=prompt_len,
                          max_new_tokens=max_new)
    sched_d = RequestScheduler(eng_d)
    for r in _repeat_prompts(cfg, prompt_len):
        sched_d.submit(r)
    t0 = time.time()
    done_d = sched_d.run()
    dt_d = time.time() - t0
    dense_bytes = eng_d.token_store_bytes()
    emit("serving/budget/dense", dt_d * 1e6,
         f"requests={done_d};slots={dense_slots};"
         f"peak_concurrent={sched_d.peak_active};"
         f"token_store_bytes={dense_bytes};"
         f"invocations={eng_d.invocations()}")

    # paged: same page-bytes, many cheap slots, admission on free pages
    pages_per_seq = -(-(prompt_len + max_new) // page_size)
    num_pages = dense_slots * pages_per_seq
    eng_p = PagedServingEngine(params, cfg, sikv, batch_size=8,
                               prompt_len=prompt_len, max_new_tokens=max_new,
                               page_size=page_size, num_pages=num_pages)
    sched_p = RequestScheduler(eng_p)
    for r in _repeat_prompts(cfg, prompt_len):
        sched_p.submit(r)
    t0 = time.time()
    done_p = sched_p.run()
    dt_p = time.time() - t0
    paged_bytes = eng_p.token_store_bytes()
    pstats = eng_p.pool_stats()
    # allocator counters via the registry, labeled by pool instance
    reg = obs.get_registry()
    pool_label = eng_p.pool.obs.labels["pool"]
    emit("serving/budget/paged", dt_p * 1e6,
         f"requests={done_p};pages={num_pages};page_size={page_size};"
         f"peak_concurrent={sched_p.peak_active};"
         f"token_store_bytes={paged_bytes};"
         f"registry_state_bytes={pstats['registry_state_bytes']};"
         f"prefix_hits={reg.value('pool.prefix_hits', pool=pool_label)};"
         f"cow_copies={reg.value('pool.cow_copies', pool=pool_label)};"
         f"evictions={reg.value('pool.evictions', pool=pool_label)};"
         f"invocations={eng_p.invocations()};"
         f"prefills={reg.value('engine.prefills', engine=eng_p.obs_label)};"
         f"steps={reg.value('engine.steps', engine=eng_p.obs_label)};"
         f"aux_launches="
         f"{reg.value('engine.aux_launches', engine=eng_p.obs_label)}")
    for uid in sorted(sched_p.completed):
        req = sched_p.completed[uid]
        emit(f"serving/budget/request/{uid}", 0.0,
             f"prompt_len={len(req.prompt)};prefix_hit={req.prefix_hit};"
             f"shared_pages={req.shared_pages};"
             f"tokens={len(req.result)}")

    ratio = sched_p.peak_active / max(1, sched_d.peak_active)
    emit("serving/budget/concurrency", 0.0,
         f"dense_peak={sched_d.peak_active};paged_peak={sched_p.peak_active};"
         f"ratio={ratio:.2f}x;"
         f"paged_bytes_over_dense={paged_bytes / dense_bytes:.3f}")
    assert done_p == done_d, (done_p, done_d)
    # the paged pool's page admission + prefix sharing hold at smoke shapes
    # too (no launch-overhead dependence), so the 2x bar is NOT relaxed
    assert_ratio("paged concurrency vs dense @ equal HBM", ratio, 2.0,
                 smoke=smoke, smoke_relaxed=2.0)
    return {"dense_peak": sched_d.peak_active,
            "paged_peak": sched_p.peak_active}


def _distinct_requests(cfg, n: int, prompt_len: int, max_new: int):
    toks = lm_sequence_batch(jax.random.PRNGKey(33), n, prompt_len,
                             cfg.vocab_size)
    return [Request(uid=i, prompt=[int(t) for t in toks[i]],
                    max_new_tokens=max_new) for i in range(n)]


def tiered_concurrency(params, cfg, sikv, *, prompt_len: int = 256,
                       page_size: int = 8, max_new: int = 8,
                       dense_slots: int = 4, n_requests: int = 14,
                       ratio_floor: float = 3.0, smoke: bool = False):
    """Headline: concurrent sequences under a FIXED device byte budget.

    The budget is what a single-tier paged pool holding ``dense_slots``
    sequences' worth of pages costs on device.  The tiered store spends the
    SAME budget on a staging pool + prefetch lane + sign-code index pages
    (``policy.tiered_pool_split``): index pages are a small fraction of a
    full page, so the same bytes index several times more tokens — and
    admission, which is per-page, sustains >= ``ratio_floor`` x the
    concurrent sequences (measured ``peak_active``; asserted at full
    shapes, relaxed at smoke shapes).  Prompts are all DISTINCT, so prefix
    sharing contributes nothing — the win is pure payload offload.
    """
    header("bench_serving: tiered vs single-tier pool @ fixed device bytes")
    cap = prompt_len + max_new
    cap += (-cap) % page_size
    pps = cap // page_size
    reqs = _distinct_requests(cfg, n_requests, prompt_len, max_new)
    batch = 4 * dense_slots

    # single-tier baseline: the pool whose device bytes define the budget
    eng_p = PagedServingEngine(params, cfg, sikv, batch_size=batch,
                               prompt_len=prompt_len, max_new_tokens=max_new,
                               page_size=page_size,
                               num_pages=dense_slots * pps)
    sched_p = RequestScheduler(eng_p)
    for r in reqs:
        sched_p.submit(Request(uid=r.uid, prompt=list(r.prompt),
                               max_new_tokens=r.max_new_tokens))
    t0 = time.time()
    done_p = sched_p.run()
    dt_p = time.time() - t0
    paged_bytes = eng_p.token_store_bytes()
    stats_p = sched_p.service_stats()
    emit("serving/tiered/paged_baseline", dt_p * 1e6,
         f"requests={done_p};pages={dense_slots * pps};"
         f"peak_concurrent={sched_p.peak_active};"
         f"device_bytes={paged_bytes};"
         f"tpot_ms={stats_p['tpot_mean'] * 1e3:.2f}")

    # tiered: the identical device budget, split by policy math
    n_layers = sum(1 for leaf in jax.tree_util.tree_leaves(
        eng_p._caches, is_leaf=lambda x: hasattr(x, "block_table"))
        if hasattr(leaf, "block_table"))
    H = eng_p._caches[0]["self"].codes.shape[1]
    D = eng_p._caches[0]["self"].head_dim
    template = init_cache(sikv, 1, H, cap, D, scale_dtype=jnp.bfloat16)
    ib, pb = page_byte_split(template, page_size)
    target = int(dense_slots * ratio_floor) + 1
    staging = staging_pages_needed(target)
    prefetch = 2
    per_layer = paged_bytes // n_layers
    bt_bytes = batch * pps * 4                 # block table, both pools pay
    budget = per_layer - bt_bytes - prefetch * 4 - 4
    num_pages = tiered_pool_split(budget, ib, pb, staging_pages=staging,
                                  prefetch_depth=prefetch)
    eng_t = TieredServingEngine(params, cfg, sikv, batch_size=batch,
                                prompt_len=prompt_len,
                                max_new_tokens=max_new,
                                page_size=page_size, num_pages=num_pages,
                                staging_pages=staging,
                                prefetch_depth=prefetch)
    sched_t = RequestScheduler(eng_t)
    for r in reqs:
        sched_t.submit(r)
    t0 = time.time()
    done_t = sched_t.run()
    dt_t = time.time() - t0
    tiered_bytes = eng_t.token_store_bytes()
    tstats = eng_t.tier_stats()
    stats_t = sched_t.service_stats()
    # cross-check: the staging hit rate recomputed from the registry's
    # transfer counters must equal what tier_stats() derives from the
    # same events — the metrics JSON a CI run uploads is trustworthy
    reg = obs.get_registry()
    xl = eng_t.xfer.obs.labels["transfer"]
    hits = (reg.value("transfer.hit_tokens", transfer=xl)
            + reg.value("transfer.prefetch_hit_tokens", transfer=xl))
    served = hits + reg.value("transfer.miss_tokens", transfer=xl)
    reg_hit_rate = hits / served if served else 1.0
    assert abs(reg_hit_rate - tstats["staging_hit_rate"]) < 1e-9, (
        reg_hit_rate, tstats["staging_hit_rate"])
    emit("serving/tiered/tiered", dt_t * 1e6,
         f"requests={done_t};index_pages={num_pages};"
         f"staging_pages={staging};prefetch_depth={prefetch};"
         f"peak_concurrent={sched_t.peak_active};"
         f"device_bytes={tiered_bytes};"
         f"host_bytes={eng_t.host_store_bytes()};"
         f"staging_hit_rate={tstats['staging_hit_rate']:.3f};"
         f"h2d_bytes_per_step={tstats['h2d_bytes_per_step']:.0f};"
         f"d2h_bytes_per_step={tstats['d2h_bytes_per_step']:.0f};"
         f"tpot_ms={stats_t['tpot_mean'] * 1e3:.2f}")

    ratio = sched_t.peak_active / max(1, sched_p.peak_active)
    tpot_pen = (stats_t["tpot_mean"] / stats_p["tpot_mean"]
                if stats_p["tpot_mean"] else 0.0)
    emit("serving/tiered/concurrency", 0.0,
         f"paged_peak={sched_p.peak_active};"
         f"tiered_peak={sched_t.peak_active};ratio={ratio:.2f}x;"
         f"device_bytes_over_budget={tiered_bytes / paged_bytes:.3f};"
         f"tpot_penalty={tpot_pen:.2f}x")
    assert done_t == done_p, (done_t, done_p)
    assert tiered_bytes <= paged_bytes, (
        f"tiered device bytes {tiered_bytes} exceed the "
        f"budget {paged_bytes}")
    assert_ratio("tiered concurrency vs single-tier @ equal device bytes",
                 ratio, ratio_floor, smoke=smoke, smoke_relaxed=1.0)
    return {"paged_peak": sched_p.peak_active,
            "tiered_peak": sched_t.peak_active, "ratio": ratio,
            "tpot_penalty": tpot_pen}


def tiered_prefetch_sweep(params, cfg, sikv, *, prompt_len: int = 128,
                          page_size: int = 8, max_new: int = 32,
                          staging_pages: int = 6,
                          depths=(0, 2, 4)):
    """Miss/hit/prefetch-depth sweep: a deliberately tight staging cache
    (long prompts, few device payload slots) forces the top-k winners onto
    host-tier pages; deeper prefetch turns synchronous ``io_callback``
    misses into lane hits at the cost of speculative transfer bytes."""
    header("bench_serving: tiered staging hit rate vs prefetch depth")
    reqs = _distinct_requests(cfg, 4, prompt_len, max_new)
    out = {}
    for depth in depths:
        eng = TieredServingEngine(params, cfg, sikv, batch_size=4,
                                  prompt_len=prompt_len,
                                  max_new_tokens=max_new,
                                  page_size=page_size,
                                  staging_pages=staging_pages,
                                  prefetch_depth=depth)
        sched = RequestScheduler(eng)
        for r in reqs:
            sched.submit(Request(uid=r.uid, prompt=list(r.prompt),
                                 max_new_tokens=r.max_new_tokens))
        t0 = time.time()
        sched.run()
        dt = time.time() - t0
        t = eng.tier_stats()
        out[depth] = t["staging_hit_rate"]
        emit(f"serving/tiered/prefetch_depth/{depth}", dt * 1e6,
             f"staging_hit_rate={t['staging_hit_rate']:.3f};"
             f"miss_tokens={t['miss_tokens']};"
             f"prefetch_hit_tokens={t['prefetch_hit_tokens']};"
             f"prefetched_pages={t['prefetched_pages']};"
             f"h2d_bytes_per_step={t['h2d_bytes_per_step']:.0f};"
             f"d2h_bytes_per_step={t['d2h_bytes_per_step']:.0f};"
             f"tpot_ms={sched.service_stats()['tpot_mean'] * 1e3:.2f}")
    return out


def chunked_admission_stall(arch: str = "llama3.1-8b", *,
                            prompt_len: int = 1024, chunk: int = 96,
                            d_model: int = 512, num_layers: int = 4,
                            live_new: int = 32, ratio_floor: float = 4.0,
                            max_ttft_regression: float = 1.10,
                            smoke: bool = False):
    """Head-of-line blocking: a live decode slot vs a long-prompt admission.

    One short request decodes ``live_new`` tokens; mid-stream a
    ``prompt_len``-token request is admitted.  Reported per policy: the
    live request's worst inter-token gap (``max_decode_stall``), the long
    request's TTFT, and the decode steps the engine ran during the long
    admission.  Acceptance: chunked admission cuts the stall by
    ``ratio_floor`` (default 4x) with TTFT within
    ``max_ttft_regression`` (default 10%; in practice chunking IMPROVES
    TTFT here, because chunks cover only ``ceil(len/chunk)`` of the padded
    prompt row while the monolithic program always pays all ``prompt_len``
    rows — the short live request admits in one chunk).

    Runs at a larger shape than the other sections (``d_model=512``, 4
    layers, 1k prompt) so the prefill is compute-bound — at toy shapes the
    per-launch dispatch overhead, not the prompt, dominates the stall.
    """
    header("bench_serving: chunked admission vs head-of-line decode stall")
    import dataclasses
    cfg = reduced_config(get_model_config(arch), num_layers=num_layers,
                         d_model=d_model)
    cfg = dataclasses.replace(cfg, dtype="float32")
    sikv = SIKVConfig(num_sink_tokens=16, token_budget=64, recent_window=8,
                      obs_window=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = lm_sequence_batch(jax.random.PRNGKey(42), 4, prompt_len,
                             cfg.vocab_size)
    short = [int(t) for t in toks[0, : max(4, prompt_len // 32)]]
    long_p = [int(t) for t in toks[1]]
    warm_short = [int(t) for t in toks[2, : max(4, prompt_len // 32)]]
    warm_long = [int(t) for t in toks[3]]

    out = {}
    for label, pc in [("whole", None), ("chunked", chunk)]:
        eng = ServingEngine(params, cfg, sikv, method="sikv", batch_size=2,
                            prompt_len=prompt_len, max_new_tokens=live_new,
                            prefill_chunk=pc)
        # warmup: compile every program this policy launches (prefill /
        # chunk / merged chunk+decode / finalize / decode) off the clock
        warm = RequestScheduler(eng)
        warm.submit(Request(uid=-1, prompt=warm_short, max_new_tokens=6))
        warm.submit(Request(uid=-2, prompt=warm_long, max_new_tokens=2))
        warm.run()

        sched = RequestScheduler(eng)
        sched.submit(Request(uid=0, prompt=short, max_new_tokens=live_new))
        sched.submit(Request(uid=1, prompt=long_p, max_new_tokens=4))
        t0 = time.time()
        sched.run()
        dt = time.time() - t0
        live, longr = sched.completed[0], sched.completed[1]
        out[label] = {"stall": live.max_stall, "ttft_long": longr.ttft,
                      "admit_decode_steps": longr.admit_decode_steps}
        emit(f"serving/stall/{label}", dt * 1e6,
             f"prefill_chunk={pc};max_decode_stall_ms="
             f"{live.max_stall * 1e3:.2f};"
             f"ttft_long_ms={longr.ttft * 1e3:.2f};"
             f"tpot_live_ms={live.tpot * 1e3:.2f};"
             f"decode_steps_during_admit={longr.admit_decode_steps};"
             f"step_token_budget={sched.step_token_budget};"
             f"max_step_tokens={sched.max_step_tokens}")

    ratio = out["whole"]["stall"] / max(out["chunked"]["stall"], 1e-9)
    ttft_reg = (out["chunked"]["ttft_long"]
                / max(out["whole"]["ttft_long"], 1e-9))
    emit("serving/stall/summary", 0.0,
         f"stall_reduction={ratio:.2f}x;ttft_regression={ttft_reg:.3f};"
         f"chunks={-(-prompt_len // chunk)}")
    assert_ratio("chunked admission stall reduction", ratio, ratio_floor,
                 smoke=smoke, smoke_relaxed=1.0, detail=str(out))
    assert_ratio("chunked admission TTFT regression", ttft_reg,
                 max_ttft_regression, ceiling=True, smoke=smoke,
                 smoke_relaxed=None, detail=str(out))
    return {"stall_reduction": ratio, "ttft_regression": ttft_reg}


def spec_decode_section(arch: str = "llama3.1-8b", *, prompt_len: int = 64,
                        max_new: int = 24, n_requests: int = 6,
                        spec_depth: int = 4, spec_draft_k: int = 4,
                        train_steps: int = 120, ratio_floor: float = 1.5,
                        smoke: bool = False):
    """Self-speculative decoding: engine launches per generated token.

    Spec decode replaces one decode launch PER TOKEN with two launches PER
    WINDOW (draft at ``spec_draft_k``, exact verify of ``spec_depth + 1``
    positions), so the launch rate drops by ``(accepted + 1) / 2`` — the
    headline TPOT lever on hardware where decode is dispatch/latency-bound.
    Acceptance is a property of the MODEL: on random weights greedy argmax
    is a coin flip under any perturbation (near-uniform logits), which
    measures nothing, so this section first trains the tiny model for
    ``train_steps`` (~20 s) on the Markov synthetic task — sharp
    conditionals give the draft a fair chance, exactly as on a real
    checkpoint.  Emitted per engine: accept rate, launches per generated
    token, and the spec/baseline launch ratio (asserted >=
    ``ratio_floor`` for the dense engine at full shapes; the paged and
    tiered rows additionally exercise page-release and staged-payload
    rollback under real traffic).  Outputs are asserted IDENTICAL to plain
    greedy decode — speculation changes the launch count, never a token.
    """
    header("bench_serving: self-speculative decoding (1-bit-index drafts)")
    import dataclasses

    from repro.launch.train import train
    params, _ = train(arch, steps=train_steps, batch=8,
                      seq_len=2 * prompt_len, d_model=128, num_layers=2,
                      lr=1e-3, log_every=max(train_steps // 2, 1))
    cfg = reduced_config(get_model_config(arch), num_layers=2, d_model=128)
    cfg = dataclasses.replace(cfg, dtype="float32")
    sikv = SIKVConfig(num_sink_tokens=8, token_budget=40, recent_window=8,
                      obs_window=8)
    toks = lm_sequence_batch(jax.random.PRNGKey(11), n_requests, prompt_len,
                             cfg.vocab_size)
    plens = [prompt_len, prompt_len // 2, 3 * prompt_len // 4]
    reqs = [Request(uid=i,
                    prompt=[int(t) for t in toks[i, : plens[i % 3]]],
                    max_new_tokens=max_new)
            for i in range(n_requests)]
    page_size = 8
    spec = dict(spec_depth=spec_depth, spec_draft_k=spec_draft_k)
    engines = {
        "baseline": lambda: ServingEngine(
            params, cfg, sikv, method="sikv", batch_size=2,
            prompt_len=prompt_len, max_new_tokens=max_new),
        "dense": lambda: ServingEngine(
            params, cfg, sikv, method="sikv", batch_size=2,
            prompt_len=prompt_len, max_new_tokens=max_new, **spec),
        "paged": lambda: PagedServingEngine(
            params, cfg, sikv, batch_size=2, prompt_len=prompt_len,
            max_new_tokens=max_new, page_size=page_size, **spec),
        "tiered": lambda: TieredServingEngine(
            params, cfg, sikv, batch_size=2, prompt_len=prompt_len,
            max_new_tokens=max_new, page_size=page_size, prefetch_depth=2,
            **spec),
    }
    out = {}
    results = {}
    for name, mk in engines.items():
        eng = mk()
        sched = RequestScheduler(eng)
        for r in reqs:
            sched.submit(Request(uid=r.uid, prompt=list(r.prompt),
                                 max_new_tokens=r.max_new_tokens))
        t0 = time.time()
        sched.run()
        dt = time.time() - t0
        stats = sched.service_stats()
        dec_toks = sum(r.decode_tokens for r in sched.completed.values())
        lpt = eng.decode_launches() / max(1, dec_toks)
        out[name] = {"lpt": lpt, "accept": stats["spec_accept_rate"]}
        results[name] = {u: sched.completed[u].result
                         for u in sched.completed}
        emit(f"serving/spec/{name}", dt * 1e6,
             f"spec_depth={eng.spec_depth};spec_draft_k={spec_draft_k};"
             f"decode_tokens={dec_toks};"
             f"decode_launches={eng.decode_launches()};"
             f"launches_per_token={lpt:.3f};"
             f"accept_rate={stats['spec_accept_rate']:.3f};"
             f"spec_steps={eng.stats.get('spec_steps', 0)};"
             f"tpot_ms={stats['tpot_mean'] * 1e3:.2f}")
        # distribution identity: speculation must never change a token
        assert results[name] == results["baseline"], (
            f"{name} spec output diverged from plain greedy decode")
        if eng.spec_depth is not None:
            # the registry's accept-depth histogram must agree with the
            # engine's scalar counters: one observation per emitting
            # window, summing to the accepted-draft total
            hist = obs.get_registry().find("engine.spec_accept_depth",
                                           engine=eng.obs_label)
            assert len(hist) == 1, hist
            h = hist[0][1]
            assert int(h.total) == eng.stats["spec_accepted"], (
                h.export(), eng.stats)
            acc_rate = (int(h.total)
                        / max(1, h.n * eng.spec_depth))
            assert abs(acc_rate - stats["spec_accept_rate"]) < 1e-9, (
                acc_rate, stats["spec_accept_rate"])
            emit(f"serving/spec/accept_depth/{name}", 0.0,
                 f"windows={h.n};mean={h.total / max(1, h.n):.2f};"
                 f"p50={h.percentile(0.5):.1f};"
                 f"p95={h.percentile(0.95):.1f};"
                 f"hist_accept_rate={acc_rate:.3f}")
    ratio = out["baseline"]["lpt"] / max(out["dense"]["lpt"], 1e-9)
    emit("serving/spec/summary", 0.0,
         f"launch_reduction={ratio:.2f}x;"
         f"accept_rate={out['dense']['accept']:.3f};"
         f"spec_depth={spec_depth};train_steps={train_steps}")
    assert_ratio("spec decode launch reduction", ratio, ratio_floor,
                 smoke=smoke, smoke_relaxed=1.0, detail=str(out))
    return {"launch_reduction": ratio,
            "accept_rate": out["dense"]["accept"]}


def _sched_workload(cfg, *, prompt_len: int, max_new: int, n_batch: int,
                    n_interactive: int, seed: int = 97):
    """Seeded bursty mixed-class workload: a saturating batch backlog
    submitted first, then an interactive burst landing behind it (arrival
    order IS the queue order).  Deterministic for a given seed."""
    toks = lm_sequence_batch(jax.random.PRNGKey(seed),
                             n_batch + n_interactive, prompt_len,
                             cfg.vocab_size)
    reqs = []
    for i in range(n_batch):
        reqs.append(Request(uid=i, prompt=[int(t) for t in toks[i]],
                            max_new_tokens=max_new, klass="batch",
                            tenant=f"t{i % 2}"))
    for j in range(n_interactive):
        i = n_batch + j
        reqs.append(Request(uid=i,
                            prompt=[int(t) for t in toks[i, : prompt_len // 4]],
                            max_new_tokens=max(2, max_new // 4),
                            klass="interactive", tenant=f"t{i % 2}"))
    return reqs


def _class_stats(sched):
    out = {}
    for klass in ("interactive", "batch"):
        mine = [r for r in sched.completed.values() if r.klass == klass]
        tt = percentiles([r.ttft for r in mine])
        tp = percentiles([t for r in mine for t in r.token_times])
        out[klass] = {"n": len(mine), "ttft_p50": tt[0], "ttft_p99": tt[2],
                      "tpot_p50": tp[0], "tpot_p99": tp[2]}
    return out


def _emit_sched_row(name, dt, sched, extra=""):
    st = _class_stats(sched)
    toks = sum(len(r.result) for r in sched.completed.values())
    kv = ";".join(
        f"{k}_{c[:3]}={st[c][k] * 1e3:.2f}"
        for c in ("interactive", "batch")
        for k in ("ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99"))
    emit(f"serving/sched/{name}", dt * 1e6,
         f"requests={len(sched.completed)};tokens={toks};"
         f"tok_per_s={toks / max(dt, 1e-9):.1f};"
         f"n_int={st['interactive']['n']};n_bat={st['batch']['n']};"
         + kv + (";" + extra if extra else ""))
    return st, toks


def sched_slo_section(params, cfg, sikv, *, prompt_len: int = 64,
                      page_size: int = 16, max_new: int = 16, batch: int = 2,
                      n_batch: int = 6, n_interactive: int = 4,
                      ttft_ceiling: float = 0.5, tput_floor: float = 0.9,
                      smoke: bool = False):
    """SLO scheduling headline (DESIGN.md §11): FIFO vs class-priority.

    The seeded bursty workload queues a slot-saturating batch backlog with
    an interactive burst behind it.  FIFO drains in arrival order, so the
    burst's TTFT (measured from SUBMIT time, not admission start) pays the
    whole backlog; the SLO scheduler's priority admission lets interactive
    jump the queue.  Acceptance: interactive p99 TTFT under SLO <=
    ``ttft_ceiling`` (0.5x) of FIFO while total throughput stays >=
    ``tput_floor`` (0.9x) — both structural (admission ORDER, not machine
    speed), so they hold at smoke shapes too.

    The overload sub-section then drives the SLO scheduler with mid-run
    interactive arrivals while every slot is held by batch work, forcing
    preemption-by-spill: a batch victim's pages demote through the tiered
    writeback protocol (host-side snapshot on the single-tier engines),
    the interactive request takes its slot, and the victim resumes
    bit-exactly.  The exactness sub-section asserts that token-stream
    identity on all three engines.
    """
    header("bench_serving: SLO scheduling (priority admission + spill)")
    mk = lambda: PagedServingEngine(params, cfg, sikv, batch_size=batch,
                                    prompt_len=prompt_len,
                                    max_new_tokens=max_new,
                                    page_size=page_size)
    stats = {}
    tputs = {}
    wtoks = lm_sequence_batch(jax.random.PRNGKey(171), 2, prompt_len,
                              cfg.vocab_size)
    for policy in ("fifo", "slo"):
        eng = mk()
        # warmup: compile every program off the clock — TTFT must measure
        # queueing policy, not first-launch compilation
        warm = RequestScheduler(eng)
        warm.submit(Request(uid=-1, prompt=[int(t) for t in wtoks[0]],
                            max_new_tokens=2))
        warm.submit(Request(uid=-2,
                            prompt=[int(t) for t in wtoks[1, : prompt_len // 4]],
                            max_new_tokens=2))
        warm.run()
        sched = (RequestScheduler(eng) if policy == "fifo"
                 else SLOScheduler(eng))
        for r in _sched_workload(cfg, prompt_len=prompt_len,
                                 max_new=max_new, n_batch=n_batch,
                                 n_interactive=n_interactive):
            assert sched.submit(r)
        t0 = time.time()
        done = sched.run()
        dt = time.time() - t0
        assert done == n_batch + n_interactive, (policy, done)
        extra = ""
        if policy == "slo":
            st = sched.service_stats()
            extra = (f"preemptions={int(st['preemptions'])};"
                     f"resumes={int(st['resumes'])};"
                     f"spilled_pages={int(st['spilled_pages'])};"
                     f"quota_deferrals={int(st['quota_deferrals'])}")
        stats[policy], toks = _emit_sched_row(policy, dt, sched, extra)
        tputs[policy] = toks / max(dt, 1e-9)

    ttft_ratio = (stats["slo"]["interactive"]["ttft_p99"]
                  / max(stats["fifo"]["interactive"]["ttft_p99"], 1e-9))
    tput_ratio = tputs["slo"] / max(tputs["fifo"], 1e-9)
    emit("serving/sched/summary", 0.0,
         f"int_ttft_p99_ratio={ttft_ratio:.3f};"
         f"tput_ratio={tput_ratio:.3f};"
         f"fifo_int_ttft_p99_ms="
         f"{stats['fifo']['interactive']['ttft_p99'] * 1e3:.2f};"
         f"slo_int_ttft_p99_ms="
         f"{stats['slo']['interactive']['ttft_p99'] * 1e3:.2f}")
    assert_ratio("SLO interactive p99 TTFT vs FIFO under bursty overload",
                 ttft_ratio, ttft_ceiling, ceiling=True, smoke=smoke,
                 smoke_relaxed=ttft_ceiling, detail=str(stats))
    assert_ratio("SLO total throughput vs FIFO", tput_ratio, tput_floor,
                 smoke=smoke, smoke_relaxed=0.75, detail=str(tputs))

    over = sched_overload_section(params, cfg, sikv, prompt_len=prompt_len,
                                  page_size=page_size, max_new=max_new,
                                  batch=batch)
    exact = sched_preempt_exactness(params, cfg, sikv,
                                    prompt_len=prompt_len,
                                    page_size=page_size)
    return {"ttft_ratio": ttft_ratio, "tput_ratio": tput_ratio,
            "overload": over, "exactness": exact}


def sched_overload_section(params, cfg, sikv, *, prompt_len: int,
                           page_size: int, max_new: int, batch: int):
    """Sustained overload: interactive bursts arrive MID-RUN while every
    slot is pinned by long batch work, so priority admission alone cannot
    help — the scheduler must spill a victim.  Asserts preemption actually
    fired, every spill resumed, the full workload completed, and no page
    leaked under a hold."""
    eng = PagedServingEngine(params, cfg, sikv, batch_size=batch,
                             prompt_len=prompt_len, max_new_tokens=max_new,
                             page_size=page_size)
    sched = SLOScheduler(eng)
    toks = lm_sequence_batch(jax.random.PRNGKey(131), batch + 4, prompt_len,
                             cfg.vocab_size)
    for i in range(batch + 1):
        assert sched.submit(Request(
            uid=i, prompt=[int(t) for t in toks[i]],
            max_new_tokens=max_new, klass="batch", tenant="t0"))
    t0 = time.time()
    # pump until the batch backlog holds every slot, then land the burst
    while len(sched._active_slots()) < batch and sched.busy:
        sched.step_once()
    for j in range(2):
        i = batch + 1 + j
        assert sched.submit(Request(
            uid=i, prompt=[int(t) for t in toks[i, : prompt_len // 4]],
            max_new_tokens=max(2, max_new // 4),
            klass="interactive", tenant="t1"))
    done = sched.run()
    dt = time.time() - t0
    st = sched.service_stats()
    _emit_sched_row("overload", dt, sched,
                    f"preemptions={int(st['preemptions'])};"
                    f"resumes={int(st['resumes'])};"
                    f"spilled_pages={int(st['spilled_pages'])}")
    assert len(sched.completed) == batch + 3, (done, sched.completed)
    assert st["preemptions"] >= 1, (
        "overload never forced a spill — shrink the pool or slots", st)
    assert st["resumes"] == st["preemptions"], st
    assert st["preempted_waiting"] == 0, st
    snap = eng.pool.snapshot()
    assert not snap["preempt_holds"], snap["preempt_holds"]
    for r in sched.completed.values():
        assert len(r.result) == r.max_new_tokens, (r.uid, len(r.result))
    return {"preemptions": int(st["preemptions"]),
            "int_ttft_p99": st["ttft_p99_interactive"],
            "bat_ttft_p99": st["ttft_p99_batch"]}


def sched_preempt_exactness(params, cfg, sikv, *, prompt_len: int,
                            page_size: int, n_steps: int = 10,
                            preempt_at: int = 4):
    """Spill/resume exactness: on each engine, decode a request straight
    through, then decode the SAME prompt with a mid-stream preempt+resume
    — the committed token streams must be bitwise identical.  The second
    run on the paged/tiered engines admits via a prefix-cache HIT (the
    first run registered the prompt), so the spill also exercises pages
    shared with the registry."""
    max_new = n_steps + 2
    engines = {
        "dense": lambda: ServingEngine(
            params, cfg, sikv, method="sikv", batch_size=2,
            prompt_len=prompt_len, max_new_tokens=max_new),
        "paged": lambda: PagedServingEngine(
            params, cfg, sikv, batch_size=2, prompt_len=prompt_len,
            max_new_tokens=max_new, page_size=page_size),
        "tiered": lambda: TieredServingEngine(
            params, cfg, sikv, batch_size=2, prompt_len=prompt_len,
            max_new_tokens=max_new, page_size=page_size, prefetch_depth=2),
    }
    toks = lm_sequence_batch(jax.random.PRNGKey(53), 1, prompt_len,
                             cfg.vocab_size)
    prompt = [int(t) for t in toks[0]]
    out = {}
    for name, mk in engines.items():
        eng = mk()

        def drive(interrupt: bool) -> list:
            eng.admit_start(0, prompt, max_new_tokens=max_new)
            first = None
            while first is None:
                first, _ = eng.admit_step()
            stream = [int(first)]
            for i in range(n_steps):
                if interrupt and i == preempt_at:
                    snap = eng.preempt_slot(0)
                    assert eng.can_resume(snap)
                    eng.resume_slot(0, snap)
                stream.append(int(eng.step()[0]))
            eng.retire(0)
            return stream

        t0 = time.time()
        base = drive(interrupt=False)
        spilled = drive(interrupt=True)
        dt = time.time() - t0
        assert spilled == base, (
            f"{name}: preempted-then-resumed stream diverged from the "
            f"uninterrupted run at "
            f"{next(i for i, (a, b) in enumerate(zip(base, spilled)) if a != b)}")
        out[name] = True
        emit(f"serving/sched/exactness/{name}", dt * 1e6,
             f"tokens={len(base)};preempt_at={preempt_at};identical=True")
    return out


if __name__ == "__main__":
    run()

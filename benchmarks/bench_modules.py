"""Table 4: head-to-head module timings.

Clustering: one-pass sign clustering vs 20-iteration K-means.
Retrieval:  LUT build + LUT-GEMV vs full-precision q.K^T vs Quest pages.
Attention:  sparse (7.5 %) fused-dequant attention vs full attention.

CPU microseconds — relative ratios are the comparable quantity (the paper's
absolute numbers are A100/4090).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header, time_fn
from repro.core import codebook as cb
from repro.core import retrieval as rtr
from repro.data.synthetic import structured_kv


def kmeans_codebook(k_sub: jax.Array, iters: int = 20, C: int = 16):
    """Reference K-means (paper's comparison): k_sub (N, d)."""
    cents = k_sub[:C]
    for _ in range(iters):
        d2 = jnp.sum((k_sub[:, None, :] - cents[None]) ** 2, -1)
        assign = jnp.argmin(d2, -1)
        onehot = jax.nn.one_hot(assign, C, dtype=k_sub.dtype)
        sums = onehot.T @ k_sub
        counts = jnp.maximum(onehot.sum(0)[:, None], 1.0)
        cents = sums / counts
    return cents


def run(L: int = 16384, D: int = 64) -> None:
    header("bench_modules (paper Table 4, 16K tokens)")
    B, H = 1, 4
    k, v = structured_kv(jax.random.PRNGKey(0), B, H, L, D)
    kn, _ = cb.normalize_keys(k)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, H, D))

    # --- clustering -------------------------------------------------------
    ours = jax.jit(lambda x: cb.build_self_index(x)[1])
    t_ours = time_fn(ours, k)
    k_sub = kn[0, 0].reshape(-1, 4)  # one group's subvectors
    G = D // 4
    km = jax.jit(functools.partial(kmeans_codebook, iters=20))
    t_km_one = time_fn(km, k_sub)
    t_km = t_km_one * G * H  # paper clusters every group/head
    emit("modules/clustering/ours_onepass", t_ours, "all groups+heads")
    emit("modules/clustering/kmeans20", t_km,
         f"extrapolated x{G * H} groups;speedup={t_km / t_ours:.1f}x")

    # --- retrieval --------------------------------------------------------
    codes, cents, mu = cb.build_self_index(k)
    lut_fn = jax.jit(lambda c, qq, ce: rtr.lut_scores(
        c, rtr.build_lut(qq, ce)))
    t_lut = time_fn(lut_fn, codes, q, cents)
    full_fn = jax.jit(lambda qq, kk: jnp.einsum("bhd,bhld->bhl", qq, kk))
    t_full = time_fn(full_fn, q, k)
    # Quest-style page scoring (page=16)
    P = L // 16
    kp = k.reshape(B, H, P, 16, D)
    kmin, kmax = kp.min(3), kp.max(3)
    quest_fn = jax.jit(lambda qq, lo, hi: jnp.sum(
        jnp.maximum(qq[:, :, None, :] * lo, qq[:, :, None, :] * hi), -1))
    t_quest = time_fn(quest_fn, q, kmin, kmax)
    emit("modules/retrieval/lut_gemv", t_lut,
         f"vs_full={t_full / t_lut:.2f}x")
    emit("modules/retrieval/full_dot", t_full, "")
    emit("modules/retrieval/quest_pages", t_quest, "page=16")

    # --- attention --------------------------------------------------------
    from repro.config import SIKVConfig
    from repro.core.attention import sikv_decode_attention, masked_attention
    from repro.core.cache import prefill_compress
    budget = int(0.075 * L)
    cfg = SIKVConfig(num_sink_tokens=64, token_budget=budget,
                     recent_window=16, obs_window=32)
    q_obs = jax.random.normal(jax.random.PRNGKey(2), (B, H, 32, D))
    cache = prefill_compress(k, v, q_obs, cfg, capacity=L + 2,
                             scale_dtype=jnp.float32)
    qd = jax.random.normal(jax.random.PRNGKey(3), (B, H, 1, D))
    k_new = jax.random.normal(jax.random.PRNGKey(4), (B, H, 1, D))
    v_new = jax.random.normal(jax.random.PRNGKey(5), (B, H, 1, D))
    sparse_fn = jax.jit(lambda *a: sikv_decode_attention(*a, cfg)[0])
    t_sparse = time_fn(sparse_fn, qd, k_new, v_new, cache)
    valid = jnp.ones(k.shape[:3], bool)
    full_attn = jax.jit(lambda *a: masked_attention(*a))
    t_fullattn = time_fn(full_attn, qd, k, v, valid)
    emit("modules/attention/sikv_sparse_7.5pct", t_sparse,
         f"budget={budget};speedup={t_fullattn / t_sparse:.2f}x")
    emit("modules/attention/full", t_fullattn, "")

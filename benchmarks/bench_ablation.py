"""Table 5: ablation of sign-in-quant, magnitude-in-retrieval, sink tokens.

Measured as decode attention-output MSE vs exact full attention on
structured caches — the mechanism behind the paper's task-accuracy deltas.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header
from repro.config import SIKVConfig
from repro.core import codebook as cb
from repro.core import quantization as qz
from repro.core import retrieval as rtr
from repro.core.attention import (full_causal_attention, group_queries,
                                  masked_attention)
from repro.core.cache import gather_dequant, prefill_compress
from repro.data.synthetic import structured_kv

BASE = SIKVConfig(num_sink_tokens=64, token_budget=256, recent_window=16,
                  obs_window=32)


def _decode_mse(k, v, q, q_obs, cfg, *, sign_only_retrieval=False,
                no_sign_quant=False) -> float:
    B, Hkv, L, D = k.shape
    cache = prefill_compress(k, v, q_obs, cfg, capacity=L,
                             scale_dtype=jnp.float32)
    q_kv = group_queries(q[:, :, 0, :], Hkv)
    if sign_only_retrieval:
        # centroids replaced by bare sign patterns: magnitude info dropped
        C, gs = cfg.codebook_size, cfg.group_size
        patterns = cb.codes_to_signs(
            jnp.arange(C, dtype=jnp.int8)[None, :], gs).reshape(C, gs)
        G = D // gs
        cents = jnp.broadcast_to(patterns, (B, Hkv, G, C, gs)).astype(
            jnp.float32)
        scores = rtr.lut_scores(cache.codes, rtr.build_lut(q_kv, cents))
    else:
        scores = rtr.lut_scores(
            cache.codes,
            rtr.build_lut(q_kv, cache.centroids.astype(jnp.float32)))
    pos = jnp.arange(cache.capacity)
    valid = (pos[None, None, :] < cache.length[:, None, None]) \
        & ~cache.sink_mask
    k_dyn = max(1, cfg.token_budget - cfg.num_sink_tokens)
    idx, vals = rtr.select_topk(
        scores, k_dyn, valid_mask=jnp.broadcast_to(valid, scores.shape))
    sel_valid = vals > jnp.finfo(scores.dtype).min / 4
    if no_sign_quant:
        # ablation: quantize K directly (2-bit, token-wise), discarding the
        # self-index sign decomposition at dequant time
        kq = qz.quantize_tokenwise(k, cfg.key_bits, cfg.quant_group)
        k_deq = qz.dequantize_tokenwise(kq)
        take = lambda x: jnp.take_along_axis(x, idx[..., None], axis=2)
        k_sel = take(k_deq)
        _, v_sel = gather_dequant(cache, idx, cfg)
    else:
        k_sel, v_sel = gather_dequant(cache, idx, cfg)
    S = cache.num_sinks
    k_all = jnp.concatenate([cache.sink_k.astype(jnp.float32), k_sel], 2)
    v_all = jnp.concatenate([cache.sink_v.astype(jnp.float32), v_sel], 2)
    valid_all = jnp.concatenate(
        [jnp.ones((B, Hkv, S), bool), sel_valid], 2)
    out = masked_attention(q, k_all, v_all, valid_all)
    ref = full_causal_attention(q, k, v, q_offset=L - 1)
    return float(jnp.mean((out - ref) ** 2))


def run(L: int = 4096) -> None:
    header("bench_ablation (paper Table 5)")
    B, Hq, Hkv, D = 1, 8, 4, 64
    key = jax.random.PRNGKey(0)
    k, v = structured_kv(key, B, Hkv, L, D)
    ks = jax.random.split(key, 2)
    q = jax.random.normal(ks[1], (B, Hq, 1, D))
    q_obs = group_queries(q[:, :, 0, :], Hkv)[:, :, None, :] \
        + 2.0 * jax.random.normal(ks[0], (B, Hkv, 32, D))

    results = {
        "ours": _decode_mse(k, v, q, q_obs, BASE),
        "wo_sign_in_quant": _decode_mse(k, v, q, q_obs, BASE,
                                        no_sign_quant=True),
        "sign_only_retrieval": _decode_mse(k, v, q, q_obs, BASE,
                                           sign_only_retrieval=True),
        "wo_sink_tokens": _decode_mse(
            k, v, q, q_obs, dataclasses.replace(BASE, num_sink_tokens=1)),
    }
    for name, mse in results.items():
        emit(f"ablation/{name}", 0.0, f"output_mse={mse:.6f}")
    # paper's ordering: every ablation hurts
    assert results["ours"] <= results["sign_only_retrieval"] + 1e-6

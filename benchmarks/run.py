"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section headers on
stdout).  ``python -m benchmarks.run [--only <name>] [--emit-json [F]]`` —
``--emit-json`` additionally writes every row as structured JSON (derived
``k=v`` pairs parsed into a dict); without an argument it writes
``BENCH_serving.json`` at the repo root — the committed trajectory file the
next PR diffs against (CI-artifact-only results are invisible to it) and
the artifact the CI smoke job uploads.

``--metrics-json [F]`` / ``--trace [F]`` additionally export the
observability layer after the suites: the metrics-registry snapshot
(launch/transfer counters, accept-depth histograms) and the Chrome
trace-event file (Perfetto-loadable).  ``--smoke`` implies both at their
default paths (``BENCH_metrics.json`` / ``BENCH_trace.json``) so the CI
smoke job uploads them as artifacts.
"""
from __future__ import annotations

import argparse
import platform
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="substring filter of benchmark module names")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: the fast suites at tiny shapes "
                         "(memory accounting + serving/paged/tiered "
                         "concurrency)")
    ap.add_argument("--emit-json", default=None, metavar="FILE",
                    nargs="?", const="BENCH_serving.json",
                    help="write all emitted rows as structured JSON "
                         "(serving + memory + every other suite run); "
                         "FILE defaults to BENCH_serving.json at the "
                         "repo root, the committed perf-trajectory file")
    ap.add_argument("--metrics-json", default=None, metavar="FILE",
                    nargs="?", const="BENCH_metrics.json",
                    help="write the metrics-registry snapshot after the "
                         "suites (default FILE: BENCH_metrics.json; "
                         "implied by --smoke)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    nargs="?", const="BENCH_trace.json",
                    help="record a Chrome trace of the serving suites and "
                         "write it after the run (default FILE: "
                         "BENCH_trace.json; implied by --smoke)")
    args = ap.parse_args()
    if args.smoke:
        args.metrics_json = args.metrics_json or "BENCH_metrics.json"
        args.trace = args.trace or "BENCH_trace.json"

    from repro import obs
    if args.metrics_json or args.trace:
        # before any suite builds an engine: handles bind at construction
        obs.set_enabled(True)
    if args.trace:
        obs.set_tracer(obs.Tracer())

    from benchmarks import (bench_ablation, bench_analysis,
                            bench_longbench_proxy, bench_memory,
                            bench_modules, bench_obs, bench_quality,
                            bench_roofline, bench_ruler_proxy,
                            bench_serving, bench_tt2t)
    if args.smoke:
        suites = [
            ("bench_memory", bench_memory.run),
            ("bench_serving",
             lambda: bench_serving.run(prompt_len=32, n_requests=4,
                                       smoke=True)),
            # disabled-mode observability overhead bound (<2%)
            ("bench_obs", lambda: bench_obs.run(smoke=True)),
            # online audit-plane recall/coverage floors (DESIGN.md §10)
            ("bench_quality", lambda: bench_quality.run(smoke=True)),
            # audit census rows (no pallas-kernel trace at smoke shapes)
            ("bench_analysis", lambda: bench_analysis.run(smoke=True)),
        ]
    else:
        suites = [
            ("bench_memory", bench_memory.run),          # Fig 5 / overhead
            ("bench_longbench_proxy", bench_longbench_proxy.run),  # Table 1
            ("bench_ruler_proxy", bench_ruler_proxy.run),          # Fig 4/T2
            ("bench_modules", bench_modules.run),        # Table 4
            ("bench_tt2t", bench_tt2t.run),              # Table 3
            ("bench_ablation", bench_ablation.run),      # Table 5
            ("bench_serving", bench_serving.run),        # batching + paged
            ("bench_quality", bench_quality.run),        # online audit floors
            ("bench_obs", bench_obs.run),                # obs overhead bound
            ("bench_roofline", bench_roofline.run),      # dry-run roofline
            ("bench_analysis", bench_analysis.run),      # §7 program census
        ]
    failures = []
    ran = []
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        ran.append(name)
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name},FAILED,{e!r}")
    print("\nname,us_per_call,derived  (all rows above)")
    if args.emit_json:
        import jax

        from benchmarks.common import RESULTS
        payload = {
            "schema": 1,
            "smoke": bool(args.smoke),
            "suites": ran,
            "platform": platform.platform(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "failures": [{"suite": n, "error": e} for n, e in failures],
            "rows": RESULTS,
        }
        from repro.obs.export import write_json_atomic
        write_json_atomic(args.emit_json, payload, indent=1)
        print(f"wrote {len(RESULTS)} rows -> {args.emit_json}")
    if args.metrics_json:
        from repro.obs.export import write_json_atomic
        snap = obs.get_registry().snapshot()
        write_json_atomic(args.metrics_json,
                          {"schema": 1, "metrics": snap}, indent=1)
        print(f"wrote {len(snap)} metric series -> {args.metrics_json}")
    if args.trace:
        n = obs.get_tracer().dump(args.trace)
        print(f"wrote {n} trace events -> {args.trace} "
              f"(load at https://ui.perfetto.dev)")
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()

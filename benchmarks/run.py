"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section headers on
stdout).  ``python -m benchmarks.run [--only <name>] [--emit-json [F]]`` —
``--emit-json`` additionally writes every row as structured JSON (derived
``k=v`` pairs parsed into a dict); without an argument it writes
``BENCH_serving.json`` at the repo root — the committed trajectory file the
next PR diffs against (CI-artifact-only results are invisible to it) and
the artifact the CI smoke job uploads.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="substring filter of benchmark module names")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: the fast suites at tiny shapes "
                         "(memory accounting + serving/paged/tiered "
                         "concurrency)")
    ap.add_argument("--emit-json", default=None, metavar="FILE",
                    nargs="?", const="BENCH_serving.json",
                    help="write all emitted rows as structured JSON "
                         "(serving + memory + every other suite run); "
                         "FILE defaults to BENCH_serving.json at the "
                         "repo root, the committed perf-trajectory file")
    args = ap.parse_args()

    from benchmarks import (bench_ablation, bench_analysis,
                            bench_longbench_proxy, bench_memory,
                            bench_modules, bench_roofline,
                            bench_ruler_proxy, bench_serving, bench_tt2t)
    if args.smoke:
        suites = [
            ("bench_memory", bench_memory.run),
            ("bench_serving",
             lambda: bench_serving.run(prompt_len=32, n_requests=4,
                                       smoke=True)),
            # audit census rows (no pallas-kernel trace at smoke shapes)
            ("bench_analysis", lambda: bench_analysis.run(smoke=True)),
        ]
    else:
        suites = [
            ("bench_memory", bench_memory.run),          # Fig 5 / overhead
            ("bench_longbench_proxy", bench_longbench_proxy.run),  # Table 1
            ("bench_ruler_proxy", bench_ruler_proxy.run),          # Fig 4/T2
            ("bench_modules", bench_modules.run),        # Table 4
            ("bench_tt2t", bench_tt2t.run),              # Table 3
            ("bench_ablation", bench_ablation.run),      # Table 5
            ("bench_serving", bench_serving.run),        # batching + paged
            ("bench_roofline", bench_roofline.run),      # dry-run roofline
            ("bench_analysis", bench_analysis.run),      # §7 program census
        ]
    failures = []
    ran = []
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        ran.append(name)
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name},FAILED,{e!r}")
    print("\nname,us_per_call,derived  (all rows above)")
    if args.emit_json:
        import jax

        from benchmarks.common import RESULTS
        payload = {
            "schema": 1,
            "smoke": bool(args.smoke),
            "suites": ran,
            "platform": platform.platform(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "failures": [{"suite": n, "error": e} for n, e in failures],
            "rows": RESULTS,
        }
        with open(args.emit_json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {len(RESULTS)} rows -> {args.emit_json}")
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()

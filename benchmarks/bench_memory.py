"""Figure 5 + "Overhead Analysis": KV-cache memory footprint vs length.

Reproduces the paper's bit accounting analytically and cross-checks it
against the actual cache arrays the implementation allocates.

Note: the paper's prose says "768L bits" but its own component list (128 sign
+ 512 quant + 256 scale/zp) sums to 896L; its headline "78% savings" matches
896/4096 = 21.9 %.  We report both and assert the 78 % claim with the
component-exact 896.  With ``sikv_bits_per_token_per_head`` defaulting to the
paper's layout minus the redundant zero-points the sign layout makes
droppable (see EXPERIMENTS §Perf), the figure is 768 exactly.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, header
from repro.config import SIKVConfig
from repro.core.cache import prefill_compress
from repro.data.synthetic import structured_kv


def sikv_bits_per_token_per_head(head_dim: int = 128, key_bits: int = 2,
                                 value_bits: int = 2, quant_group: int = 32,
                                 scale_bits: int = 16,
                                 store_zero_points: bool = False) -> int:
    """Per-token, per-head cache bits of the SIKV layout.

    ``store_zero_points=False`` is the optimized layout: |K|/alpha lives in
    [0, 1] and V zero-points fold into the scale pair only when needed — the
    paper's stated 768L figure corresponds to one 16-bit parameter per group
    for each of K and V (the other folded), its component list to two.
    """
    sign = head_dim                                    # 1 bit/channel
    kq = key_bits * head_dim
    vq = value_bits * head_dim
    groups = head_dim // quant_group
    params_per_group = 2 if store_zero_points else 1
    meta = 2 * groups * params_per_group * scale_bits  # K and V
    return sign + kq + vq + meta


def run() -> None:
    header("bench_memory (paper Fig. 5 / Overhead Analysis)")
    D = 128
    fp16 = 2 * D * 16
    for store_zp, label in [(True, "paper-components"),
                            (False, "optimized-768")]:
        bits = sikv_bits_per_token_per_head(store_zero_points=store_zp)
        emit(f"memory/bits_per_token_head/{label}", 0.0,
             f"bits={bits};fp16={fp16};ratio={fp16 / bits:.2f}x;"
             f"savings={100 * (1 - bits / fp16):.1f}%")

    # actual allocation cross-check (D=128, includes sink_mask byte)
    cfg = SIKVConfig()
    B, H, L = 1, 2, 2048
    k, v = structured_kv(jax.random.PRNGKey(0), B, H, L, D)
    q_obs = jax.random.normal(jax.random.PRNGKey(1), (B, H, 32, D))
    cache = prefill_compress(k, v, q_obs, cfg)
    token_bytes = 0
    fixed_bytes = 0
    for name, arr in cache._asdict().items():
        if arr.ndim >= 3 and arr.shape[2] == cache.capacity:
            token_bytes += arr.nbytes / (B * H * L)
        else:
            fixed_bytes += arr.nbytes
    fp16_bytes = 2 * D * 2
    emit("memory/actual_bytes_per_token_head", 0.0,
         f"bytes={token_bytes:.1f};fp16={fp16_bytes};"
         f"ratio={fp16_bytes / token_bytes:.2f}x;"
         f"fixed_overhead_bytes={fixed_bytes}")

    # footprint vs prompt length (Fig. 5 x-axis), llama3.1-8B whole model
    n_layers, n_kv = 32, 8
    for L in [8192, 16384, 32768, 65536, 131072]:
        full = n_layers * n_kv * L * fp16_bytes / 2**30
        ours = n_layers * n_kv * L * (
            sikv_bits_per_token_per_head() / 8) / 2**30
        emit(f"memory/llama8b_cache_gib/L={L}", 0.0,
             f"fp16={full:.2f}GiB;sikv={ours:.2f}GiB;"
             f"ratio={full / ours:.2f}x")

    paged_vs_dense()
    tiered_vs_paged()


def _dense_token_bytes(cache) -> int:
    """Bytes of the token-indexed arrays of a dense cache (incl. sink_mask
    metadata), excluding the fixed per-slot state both layouts share."""
    return sum(arr.nbytes for arr in cache._asdict().values()
               if arr.ndim >= 3 and arr.shape[2] == cache.capacity)


def paged_vs_dense(*, Lmax: int = 2048, page_size: int = 64,
                   B: int = 4, H: int = 2, D: int = 128) -> None:
    """MEASURED paged-pool HBM vs dense per-slot allocation (allocated
    jax arrays, ``nbytes``) at several request-length mixes.

    Dense reserves ``B * Lmax`` tokens regardless of load; the pool holds
    exactly the pages the mix touches (plus the block table).  The
    ``shared-prompts`` mix shows prefix caching: identical prompts store
    their pages once.
    """
    header("bench_memory: paged pool vs dense per-slot (measured)")
    from repro.core.cache import init_cache
    from repro.paged.cache import init_paged_cache, paged_token_bytes

    cfg = SIKVConfig()
    dense = init_cache(cfg, B, H, Lmax, D)
    dense_bytes = _dense_token_bytes(dense)
    template = init_cache(cfg, 1, H, Lmax, D)

    pages = lambda length: -(-length // page_size)
    mixes = {
        "uniform-max": [Lmax] * B,
        "mixed": [Lmax, Lmax // 2, Lmax // 4, Lmax // 8],
        "uniform-short": [Lmax // 8] * B,
    }
    for name, lengths in mixes.items():
        num_pages = sum(pages(l) for l in lengths)
        paged = init_paged_cache(template, num_pages, page_size, B)
        pb = paged_token_bytes(paged)
        emit(f"memory/paged_vs_dense/{name}", 0.0,
             f"lengths={lengths};pages={num_pages};paged_bytes={pb};"
             f"dense_bytes={dense_bytes};ratio={dense_bytes / pb:.2f}x")

    # prefix sharing: B identical full-length prompts -> one page set
    num_pages = pages(Lmax)
    paged = init_paged_cache(template, num_pages, page_size, B)
    pb = paged_token_bytes(paged)
    emit("memory/paged_vs_dense/shared-prompts", 0.0,
         f"lengths={[Lmax] * B};pages={num_pages};paged_bytes={pb};"
         f"dense_bytes={dense_bytes};ratio={dense_bytes / pb:.2f}x")


def tiered_vs_paged(*, Lmax: int = 2048, page_size: int = 64,
                    B: int = 4, H: int = 2, D: int = 128,
                    staging_pages: int = 6, prefetch_depth: int = 4) -> None:
    """MEASURED device bytes of the tiered store vs the single-tier pool at
    the SAME indexable token capacity, plus the inverse view: tokens a
    fixed device budget can index under each layout.

    The tiered layout keeps only the sign-code index (+ tier map) on device
    per page; the payload lives host-side and rotates through the
    ``staging_pages`` device slots — so per-page device cost collapses from
    index+payload to index, and capacity per device byte expands by nearly
    the payload/index ratio once the fixed staging cost is amortized.
    """
    header("bench_memory: tiered store vs single-tier pool (measured)")
    from repro.core.cache import init_cache
    from repro.core.policy import tiered_pool_split
    from repro.paged.cache import init_paged_cache, paged_token_bytes
    from repro.tiered.cache import (init_tiered_cache, page_byte_split,
                                    tiered_device_bytes)

    cfg = SIKVConfig()
    template = init_cache(cfg, 1, H, Lmax, D)
    ib, pb_page = page_byte_split(template, page_size)
    num_pages = B * (Lmax // page_size)

    paged = init_paged_cache(template, num_pages, page_size, B)
    single = paged_token_bytes(paged)
    tiered = init_tiered_cache(template, num_pages, page_size,
                               staging_pages, prefetch_depth, B, 0)
    dev = tiered_device_bytes(tiered)
    host = num_pages * pb_page
    emit("memory/tiered_vs_paged/same-capacity", 0.0,
         f"pages={num_pages};index_bytes_page={ib};"
         f"payload_bytes_page={pb_page};single_tier_bytes={single};"
         f"tiered_device_bytes={dev};tiered_host_bytes={host};"
         f"device_shrink={single / dev:.2f}x")

    # inverse: tokens indexable under the single-tier pool's byte budget
    budget = single
    p2 = tiered_pool_split(budget, ib, pb_page,
                           staging_pages=staging_pages,
                           prefetch_depth=prefetch_depth)
    emit("memory/tiered_vs_paged/same-budget", 0.0,
         f"budget_bytes={budget};single_tier_tokens={num_pages * page_size};"
         f"tiered_tokens={p2 * page_size};"
         f"expansion={p2 / num_pages:.2f}x")
    assert dev < single
    assert p2 > num_pages

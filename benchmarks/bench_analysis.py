"""Audit-census benchmark rows: the launch/transfer shape of every traced
engine program, emitted through the common harness so the perf-trajectory
JSON (BENCH_serving.json) carries the program contracts next to the
timings they explain.

Rows:

* ``analysis/trace`` — wall time to trace + lower the whole program set
  (the cost CI's ``analysis`` job pays per run), with suite totals.
* ``analysis/<program>`` — one row per audited program; ``derived`` holds
  the census counters (pallas launches, io/pure callbacks, device_puts,
  their in-loop variants) plus whether the lowering donates its cache
  operand.  These are the same numbers ANALYSIS_BUDGET.json pins; the
  benchmark row makes drift visible in the perf artifact too.
* ``analysis/protocol_<harness>`` — exhaustive page-protocol exploration
  throughput (DESIGN.md §9): wall time per explored state, with the
  state/transition counts at the gate's smoke depth in ``derived``.
* ``analysis/protocol_guard`` — cost of one ``check_view`` pass over a
  populated harness, i.e. the per-scheduler-step overhead a serve run
  pays under ``--check-invariants``.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, header


def run(smoke: bool = False) -> None:
    from repro.analysis import build_suite

    header("analysis: program census (jaxpr audit, DESIGN.md §7)")
    t0 = time.perf_counter()
    suite = build_suite(kernels=not smoke)
    trace_us = (time.perf_counter() - t0) * 1e6

    violations = suite.audit()
    totals = {"programs": len(suite.programs),
              "violations": len(violations)}
    for prog in suite.programs:
        for k, v in prog.census.counts.items():
            if v:
                totals[k] = totals.get(k, 0) + v
    emit("analysis/trace", trace_us,
         ";".join(f"{k}={v}" for k, v in sorted(totals.items())))

    for prog in suite.programs:
        cen = prog.census
        parts = [f"{k}={v}" for k, v in cen.counts.items() if v]
        parts.append(f"donates={int(prog.donates)}")
        emit(f"analysis/{prog.name}", 0.0, ";".join(parts))

    assert not violations, \
        f"program contracts violated: {[str(v) for v in violations]}"

    from repro.analysis import protocol

    header("analysis: page-protocol explorer (DESIGN.md §9)")
    harnesses = [("paged", protocol.make_paged_harness, 6 if smoke else 9),
                 ("tiered", protocol.make_tiered_harness, 5 if smoke else 8),
                 ("tiered_spec",
                  lambda: protocol.make_tiered_harness(spec=True),
                  5 if smoke else 7)]
    bad = []
    for label, make, depth in harnesses:
        res = protocol.explore(make, depth=depth)
        us_per_state = res.elapsed * 1e6 / max(1, res.states)
        emit(f"analysis/protocol_{label}", us_per_state,
             f"states={res.states};transitions={res.transitions};"
             f"depth={res.depth};"
             f"states_per_s={res.states / max(res.elapsed, 1e-9):.0f}")
        if res.violation is not None:
            bad.append(f"{label}: {res.violation}")

    # guard overhead: check_view on a harness with both slots live (the
    # densest state a scheduler-step boundary sees at this shape)
    h = protocol.make_tiered_harness()
    for ev in [("admit_start", "A"), ("admit_finish",),
               ("admit_start", "B"), ("admit_finish",), ("decode", 0)]:
        bad += [f"guard-setup {ev}: {f}" for f in h.apply(ev)]
    view = h.view()
    n = 200 if smoke else 2000
    t0 = time.perf_counter()
    for _ in range(n):
        bad += protocol.check_view(view)
    guard_us = (time.perf_counter() - t0) * 1e6 / n
    emit("analysis/protocol_guard", guard_us,
         f"pages={h.pool.num_pages};slots={h.num_slots}")

    assert not bad, f"page protocol violated: {bad[:4]}"

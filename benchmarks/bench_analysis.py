"""Audit-census benchmark rows: the launch/transfer shape of every traced
engine program, emitted through the common harness so the perf-trajectory
JSON (BENCH_serving.json) carries the program contracts next to the
timings they explain.

Rows:

* ``analysis/trace`` — wall time to trace + lower the whole program set
  (the cost CI's ``analysis`` job pays per run), with suite totals.
* ``analysis/<program>`` — one row per audited program; ``derived`` holds
  the census counters (pallas launches, io/pure callbacks, device_puts,
  their in-loop variants) plus whether the lowering donates its cache
  operand.  These are the same numbers ANALYSIS_BUDGET.json pins; the
  benchmark row makes drift visible in the perf artifact too.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, header


def run(smoke: bool = False) -> None:
    from repro.analysis import build_suite

    header("analysis: program census (jaxpr audit, DESIGN.md §7)")
    t0 = time.perf_counter()
    suite = build_suite(kernels=not smoke)
    trace_us = (time.perf_counter() - t0) * 1e6

    violations = suite.audit()
    totals = {"programs": len(suite.programs),
              "violations": len(violations)}
    for prog in suite.programs:
        for k, v in prog.census.counts.items():
            if v:
                totals[k] = totals.get(k, 0) + v
    emit("analysis/trace", trace_us,
         ";".join(f"{k}={v}" for k, v in sorted(totals.items())))

    for prog in suite.programs:
        cen = prog.census
        parts = [f"{k}={v}" for k, v in cen.counts.items() if v]
        parts.append(f"donates={int(prog.donates)}")
        emit(f"analysis/{prog.name}", 0.0, ";".join(parts))

    assert not violations, \
        f"program contracts violated: {[str(v) for v in violations]}"

"""Observability overhead: the disabled mode must be ~free.

The instrumentation contract (DESIGN.md §8) is that components bind
their metric/tracer handles at construction, so a disabled registry
costs one attribute load plus one empty call per seam.  This suite
turns that into a measured bound:

1. microbenchmark the no-op handles (``NULL_COUNTER.inc``, the null
   tracer's ``instant``/``span``) to get a per-call cost;
2. run the smoke serving workload once with observability DISABLED
   (wall time ``W_d``) and once ENABLED with a tracer, counting every
   event/observation the workload actually produces;
3. bound the disabled-mode overhead as
   ``calls * per_call_cost / W_d`` — a deliberate OVERestimate (the
   call count is padded 2x for gauge sets and handle loads the
   snapshot cannot see) — and assert it stays under 2% (smoke-relaxed
   per the ``assert_ratio`` convention).

The analytic bound is used instead of differencing two wall-clock runs
because at these shapes the run-to-run jitter of jitted-program
dispatch (>5%) would drown a sub-2% effect; the no-op cost itself is
measured, not modeled.

A fourth run serves the same workload with the retrieval-quality audit
probe sampling every 16th decode step (DESIGN.md §10) and asserts the
audited wall time stays within a small factor of the unaudited run —
the sampled probe must stay cheap enough to leave on in production.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import assert_ratio, emit, header
from repro import obs
from repro.config import SIKVConfig, get_model_config, reduced_config
from repro.data.synthetic import lm_sequence_batch
from repro.models import init_params
from repro.obs.metrics import NULL_COUNTER
from repro.obs.trace import NULL_TRACER
from repro.serving import Request, RequestScheduler, ServingEngine


def _noop_cost_us(iters: int = 200_000) -> float:
    """Worst per-call wall cost (µs) across the disabled-mode handles."""
    t0 = time.perf_counter()
    for _ in range(iters):
        NULL_COUNTER.inc()
    t1 = time.perf_counter()
    for _ in range(iters):
        NULL_TRACER.instant("track", "name", uid=0, n=1)
    t2 = time.perf_counter()
    for _ in range(iters):
        with NULL_TRACER.span("track", "name"):
            pass
    t3 = time.perf_counter()
    return max(t1 - t0, t2 - t1, t3 - t2) / iters * 1e6


def _serve_once(params, cfg, sikv, *, batch, prompt_len, max_new,
                n_requests, audit_every=None, out=None) -> float:
    """One continuous-batching flush; returns wall seconds.  ``out``
    (a dict) receives the engine's launch stats when passed."""
    eng = ServingEngine(params, cfg, sikv, method="sikv",
                        batch_size=batch, prompt_len=prompt_len,
                        max_new_tokens=max_new, audit_every=audit_every)
    sched = RequestScheduler(eng)
    toks = lm_sequence_batch(jax.random.PRNGKey(5), n_requests,
                             prompt_len, cfg.vocab_size)
    news = [max_new, max_new // 2, max_new // 4]
    for i in range(n_requests):
        sched.submit(Request(uid=i, prompt=[int(t) for t in toks[i]],
                             max_new_tokens=news[i % len(news)]))
    t0 = time.perf_counter()
    sched.run()
    dt = time.perf_counter() - t0
    if out is not None:
        out.update(eng.stats)
    return dt


def _count_observations() -> int:
    """Total mutator calls visible in the live registry (counters count
    their value — every serving-seam counter here increments by 1 — and
    histograms their observation count)."""
    n = 0
    for series in obs.get_registry().snapshot().values():
        for s in series.values():
            if s["type"] == "counter":
                n += int(s["value"])
            elif s["type"] == "histogram":
                n += int(s["n"])
    return n


def run(*, prompt_len: int = 32, max_new: int = 16, batch: int = 2,
        n_requests: int = 4, arch: str = "llama3.1-8b",
        smoke: bool = False):
    header("bench_obs (disabled-mode observability overhead)")
    import dataclasses
    cfg = reduced_config(get_model_config(arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    sikv = SIKVConfig(num_sink_tokens=8, token_budget=28, recent_window=4,
                      obs_window=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    shape = dict(batch=batch, prompt_len=prompt_len, max_new=max_new,
                 n_requests=n_requests)

    per_call_us = _noop_cost_us()
    emit("obs/noop_cost", per_call_us, "per disabled-mode handle call")

    # this suite flips the process-wide registry/tracer; other suites in
    # the same run (and the harness's --trace export) must get their
    # state back untouched
    reg = obs.get_registry()
    saved_series = dict(reg._series)
    saved_enabled = reg.enabled
    saved_tracer = obs.get_tracer()
    try:
        # disabled run (warm the jit caches off the clock, first flush)
        obs.set_enabled(False, reset=True)
        obs.set_tracer(obs.NULL_TRACER)
        _serve_once(params, cfg, sikv, **shape)
        w_disabled = _serve_once(params, cfg, sikv, **shape)
        emit("obs/serve_disabled", w_disabled * 1e6, "obs off")

        # enabled run: same workload, count everything it records
        obs.set_enabled(True, reset=True)
        tracer = obs.set_tracer(obs.Tracer(capacity=1 << 20))
        w_enabled = _serve_once(params, cfg, sikv, **shape)
        n_trace = len(tracer.events())
        n_metrics = _count_observations()
        # 2x pad: gauge sets, handle loads, and CounterGroup dict upkeep
        # are invisible to the snapshot but cost about one no-op call each
        calls = 2 * (n_trace + n_metrics)

        # sampled-audit run (DESIGN.md §10): every 16th decode step pays
        # the exact-rescoring probe and the host-side histogram fold.
        # First flush warms the probe's compile off the clock, like the
        # disabled run's warm-up above.
        obs.set_enabled(True, reset=True)
        obs.set_tracer(obs.Tracer(capacity=1 << 20))
        stats: dict = {}
        _serve_once(params, cfg, sikv, audit_every=16, **shape)
        w_audited = _serve_once(params, cfg, sikv, audit_every=16,
                                out=stats, **shape)
    finally:
        reg._series.clear()
        reg._series.update(saved_series)
        reg.enabled = saved_enabled
        obs.set_tracer(saved_tracer)

    overhead = (calls * per_call_us * 1e-6) / w_disabled
    emit("obs/serve_enabled", w_enabled * 1e6,
         f"trace_events={n_trace};metric_observations={n_metrics};"
         f"enabled_over_disabled={w_enabled / w_disabled:.3f}x")
    emit("obs/disabled_overhead", 0.0,
         f"bound_calls={calls};per_call_us={per_call_us:.4f};"
         f"overhead_frac={overhead:.5f};bar=0.02")
    assert_ratio("disabled-mode observability overhead", overhead, 0.02,
                 ceiling=True, smoke=smoke, smoke_relaxed=0.05,
                 detail=f"{calls} calls x {per_call_us:.4f}us over "
                        f"{w_disabled * 1e3:.1f}ms")
    audit_factor = w_audited / w_disabled
    emit("obs/serve_audited", w_audited * 1e6,
         f"audit_every=16;audit_steps={stats.get('audit_steps', 0)};"
         f"steps={stats.get('steps', 0)};"
         f"audited_over_disabled={audit_factor:.3f}x;bar=2.0")
    # the probe is roughly one extra decode-shaped launch per sampled
    # step, so at 1/16 sampling the whole serve must stay well under 2x
    # the unaudited wall time (smoke shapes: dispatch jitter dominates,
    # relax to 3x)
    assert_ratio("sampled-audit serving overhead (audit_every=16)",
                 audit_factor, 2.0, ceiling=True, smoke=smoke,
                 smoke_relaxed=3.0,
                 detail=f"{stats.get('audit_steps', 0)} probes over "
                        f"{stats.get('steps', 0)} steps")
    return {"overhead": overhead, "noop_us": per_call_us,
            "audit_factor": audit_factor}


if __name__ == "__main__":
    run()

"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import os

from benchmarks.common import emit, header
from repro.roofline import load_records, roofline_terms

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def run() -> None:
    header("bench_roofline (from dry-run artifacts)")
    recs = load_records(os.path.abspath(ART))
    if not recs:
        print("roofline/no_artifacts,0.0,run repro.launch.dryrun first")
        return
    for rec in recs:
        r = roofline_terms(rec)
        mesh = "x".join(str(s) for s in rec["mesh"])
        emit(f"roofline/{rec['arch']}/{rec['shape']}/{mesh}",
             r["bound_s"] * 1e6,
             f"compute={r['compute_s']:.3e}s;memory={r['memory_s']:.3e}s;"
             f"collective={r['collective_s']:.3e}s;bound={r['dominant']};"
             f"useful={r['useful_ratio']:.2f}")

"""Online retrieval-quality audit: recall/coverage floors for the
self-index, measured by the sampled audit plane on a live tiered+spec
serving run (DESIGN.md §10).

Unlike the LongBench/Ruler proxies (offline, one synthetic cache), this
suite exercises the PRODUCTION telemetry path: a ``TieredServingEngine``
with speculative decode serves a continuous-batching workload with
``audit_every=2``; every sampled decode step runs the non-donating audit
probe (exact fp re-scoring over sinks+ring+quant), the scheduler folds
the per-layer/per-head metrics into the registry's ``audit.*``
histogram families, and this suite reads them back via
``audit_summary`` and asserts quality floors:

* **recall@k** of the sign-code top-k against the exact-score top-k —
  the paper's headline retrieval claim, now measured in-loop;
* **attention-mass coverage** of the selected set (sinks + recents +
  retrieved) under the true softmax — how much probability mass the
  sparse step actually sees.

Per-layer rows surface WHERE quality degrades (the crippled-index test
in ``tests/test_audit.py`` proves a broken layer is visibly flagged);
the tiered engine additionally reports staging-hit-weighted recall and
draft-vs-verify divergence for the speculative path.
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import assert_ratio, emit, header
from repro import obs
from repro.config import SIKVConfig, get_model_config, reduced_config
from repro.data.synthetic import lm_sequence_batch
from repro.models import init_params
from repro.obs.audit import audit_summary
from repro.serving import Request, RequestScheduler
from repro.serving.tiered_engine import TieredServingEngine

# floors calibrated on the reduced-config smoke shapes below: measured
# recall ~0.70 / coverage ~0.45 at prompt 64, budget 32.  The floors sit
# well under the measured means (quality regressions of interest — a
# mis-trained index, a selection bug — crater recall to <0.2, see the
# crippled-index test) while leaving room for seed jitter.
RECALL_FLOOR = 0.50
COVERAGE_FLOOR = 0.35


def run(*, prompt_len: int = 64, max_new: int = 16, batch: int = 2,
        n_requests: int = 4, arch: str = "llama3.1-8b",
        smoke: bool = False):
    header("bench_quality (online retrieval-quality audit)")
    cfg = reduced_config(get_model_config(arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    sikv = SIKVConfig(num_sink_tokens=8, token_budget=32, recent_window=4,
                      obs_window=8)
    params = init_params(jax.random.PRNGKey(0), cfg)

    # the audit metrics flow through the process-wide registry/tracer;
    # save and restore so other suites in the same run are untouched
    reg = obs.get_registry()
    saved_series = dict(reg._series)
    saved_enabled = reg.enabled
    saved_tracer = obs.get_tracer()
    try:
        obs.set_enabled(True, reset=True)
        obs.set_tracer(obs.Tracer(capacity=1 << 20))
        eng = TieredServingEngine(
            params, cfg, sikv, batch_size=batch, prompt_len=prompt_len,
            max_new_tokens=max_new, page_size=4, prefetch_depth=1,
            spec_depth=2, spec_draft_k=4, audit_every=2)
        sched = RequestScheduler(eng)
        toks = lm_sequence_batch(jax.random.PRNGKey(11), n_requests,
                                 prompt_len, cfg.vocab_size)
        for i in range(n_requests):
            sched.submit(Request(uid=i, prompt=[int(t) for t in toks[i]],
                                 max_new_tokens=max_new))
        sched.run()
        st = sched.service_stats()
        summary = audit_summary(reg, engine=eng.obs_label)
    finally:
        reg._series.clear()
        reg._series.update(saved_series)
        reg.enabled = saved_enabled
        obs.set_tracer(saved_tracer)

    per_layer = summary["per_layer"]
    overall = summary["overall_mean"]
    # per-layer rows for the headline families: this is the demo the
    # audit plane exists for — recall/coverage per transformer layer on
    # a live tiered+spec run, plus the spec-path attribution families
    for metric in ("recall", "coverage", "staged_recall", "draft_recall"):
        for layer, s in sorted(per_layer.get(metric, {}).items()):
            emit(f"quality/{metric}/layer{layer}", 0.0,
                 f"n={s['n']};mean={s['mean']:.3f};min={s['min']:.3f}")
    emit("quality/overall", 0.0,
         f"audit_steps={st.get('n_audited', 0)};"
         f"recall={overall.get('recall', 0.0):.3f};"
         f"coverage={overall.get('coverage', 0.0):.3f};"
         f"draft_divergence={overall.get('draft_divergence', 0.0):.3f};"
         f"recall_floor={RECALL_FLOOR};coverage_floor={COVERAGE_FLOOR}")

    assert st.get("n_audited", 0) > 0, (
        "audit plane produced no samples — sampling or the scheduler "
        "bridge is broken")
    assert_ratio("self-index recall@k (online audit)",
                 overall.get("recall", 0.0), RECALL_FLOOR,
                 smoke=smoke, smoke_relaxed=RECALL_FLOOR,
                 detail=f"{st.get('n_audited', 0)} sampled steps")
    assert_ratio("selected-set attention-mass coverage (online audit)",
                 overall.get("coverage", 0.0), COVERAGE_FLOOR,
                 smoke=smoke, smoke_relaxed=COVERAGE_FLOOR,
                 detail=f"{st.get('n_audited', 0)} sampled steps")
    return {"recall": overall.get("recall", 0.0),
            "coverage": overall.get("coverage", 0.0),
            "n_audited": st.get("n_audited", 0)}


if __name__ == "__main__":
    run()

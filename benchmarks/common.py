"""Shared benchmark utilities: timing, CSV emission, synthetic workloads."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List

import jax

ROWS: List[str] = []
# structured mirror of ROWS for --emit-json (benchmarks/run.py): the
# ``derived`` k=v;k=v string parsed into a dict, numbers as numbers
RESULTS: List[Dict[str, Any]] = []


def _parse_derived(derived: str) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for part in filter(None, derived.split(";")):
        if "=" not in part:
            out.setdefault("notes", []).append(part)
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v.rstrip("x%"))
            except ValueError:
                out[k] = v
    return out


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    RESULTS.append({"name": name, "us_per_call": round(us_per_call, 2),
                    "derived": _parse_derived(derived)})
    print(row)


def assert_ratio(label: str, measured: float, threshold: float, *,
                 smoke: bool = False, smoke_relaxed: float | None = None,
                 ceiling: bool = False, detail: str = "") -> None:
    """One definition of the benchmark acceptance bar.

    Full shapes assert ``measured >= threshold`` (``<=`` with
    ``ceiling=True``).  At smoke shapes — the CI job's tiny dims, where
    per-launch dispatch overhead, not the modeled effect, dominates — the
    bar drops to ``smoke_relaxed`` (``None`` skips the check entirely).
    PR2–PR4 each re-implemented this inline; every acceptance assertion
    routes through here now.
    """
    bar = smoke_relaxed if smoke else threshold
    if bar is None:
        return
    ok = measured <= bar if ceiling else measured >= bar
    assert ok, (
        f"{label}: measured {measured:.3f}, required "
        f"{'<=' if ceiling else '>='} {bar}"
        f"{' (smoke-relaxed)' if smoke else ''}"
        f"{'; ' + detail if detail else ''}")


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time in microseconds (jit-compiled callables)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def header(title: str) -> None:
    print(f"\n# --- {title} " + "-" * max(8, 60 - len(title)))

"""Shared benchmark utilities: timing, CSV emission, synthetic workloads."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row)


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time in microseconds (jit-compiled callables)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def header(title: str) -> None:
    print(f"\n# --- {title} " + "-" * max(8, 60 - len(title)))

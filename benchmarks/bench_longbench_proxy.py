"""Table 1 proxy (LongBench, budget=160): retrieval recall and attention-
output fidelity of each method on structured synthetic caches.

Offline CPU containers can't run the 8B/14B checkpoints the paper evaluates;
accuracy on LongBench flows through (a) whether the right tokens are
attended and (b) how faithful the attended values are.  Both are measured
directly: recall@budget vs exact top-k, and output MSE vs full attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header
from repro.config import SIKVConfig
from repro.core.attention import full_causal_attention
from repro.data.synthetic import structured_kv
from repro.sparse import get_method

METHODS = ["sikv", "sikv16", "snapkv", "quest", "double_sparse", "kivi",
           "full"]


def run(budget: int = 160, L: int = 4096, trials: int = 3) -> None:
    header("bench_longbench_proxy (paper Table 1, budget=160)")
    B, Hq, Hkv, D = 1, 8, 4, 64
    cfg = SIKVConfig(num_sink_tokens=64, token_budget=budget,
                     recent_window=16, obs_window=32)
    errs = {m: [] for m in METHODS}
    recalls = {m: [] for m in METHODS}
    audits = []          # shared-definition (recall, coverage) per trial
    import dataclasses
    cfg16 = dataclasses.replace(cfg, key_bits=8, value_bits=8)
    for t in range(trials):
        key = jax.random.PRNGKey(t)
        k, v = structured_kv(key, B, Hkv, L, D)
        ks = jax.random.split(key, 4)
        # observation queries WEAKLY correlated with the decode query —
        # decode drifts away from the prefill tail (the regime where static
        # pruning fails and dynamic retrieval matters; with perfectly
        # predictive obs queries SnapKV is an oracle and the comparison
        # degenerates)
        q = jax.random.normal(ks[1], (B, Hq, 1, D))
        from repro.core.attention import group_queries
        q_kv = group_queries(q[:, :, 0, :], Hkv)
        # the observation window does NOT predict the decode query (the
        # LongBench/Ruler regime the paper targets: the question arrives
        # after the context; SnapKV's Table-2 NS-task collapse is exactly
        # this) — votes capture generic salience only
        q_obs = jax.random.normal(ks[0], (B, Hkv, 32, D))
        # query-specific evidence tokens (LongBench QA regime): a handful of
        # keys align with THIS query, unpredictable from the obs window —
        # static pruning cannot keep them, dynamic retrieval must find them
        from repro.data.synthetic import scatter_rows
        n_needles = 16
        pos = jax.random.choice(jax.random.fold_in(key, 7), L,
                                (B, Hkv, n_needles), replace=False)
        qn = q_kv / jnp.linalg.norm(q_kv, axis=-1, keepdims=True)
        # norm-matched: needles are distinguished by DIRECTION (query
        # alignment) only — norm-based generic salience must not reveal them
        bg_norm = jnp.mean(jnp.linalg.norm(k, axis=-1), axis=2)  # (B, Hkv)
        needle_k = (qn * bg_norm[..., None])[:, :, None, :] \
            + 0.2 * jax.random.normal(
                jax.random.fold_in(key, 8), (B, Hkv, n_needles, D))
        k = scatter_rows(k, pos, needle_k)
        v = scatter_rows(v, pos, 3.0 * jax.random.normal(
            jax.random.fold_in(key, 9), (B, Hkv, n_needles, D)))
        k_new = jax.random.normal(ks[2], (B, Hkv, 1, D)) * 0.1
        v_new = jax.random.normal(ks[3], (B, Hkv, 1, D)) * 0.1
        ref = full_causal_attention(
            q, jnp.concatenate([k, k_new], 2), jnp.concatenate([v, v_new], 2),
            q_offset=L)
        exact_scores = jnp.einsum("bhd,bhld->bhl", q_kv, k)
        ie = jax.lax.top_k(exact_scores, budget)[1]
        for m in METHODS:
            meth = get_method("sikv" if m == "sikv16" else m,
                              cfg16 if m == "sikv16" else cfg)
            cache = meth.prefill(k, v, q_obs, capacity=L + 8)
            out, _ = meth.decode(q, k_new, v_new, cache)
            errs[m].append(float(jnp.mean((out - ref) ** 2)))
            # recall of the exact top-'budget' under each method's selection
            if m == "sikv":  # recall only once (selection is bit-identical for sikv16)
                from repro.core import retrieval as rtr
                scores = rtr.lut_scores(
                    cache.codes[:, :, :L],
                    rtr.build_lut(q_kv, cache.centroids.astype(jnp.float32)))
                ia = jax.lax.top_k(scores, budget)[1]
                rec = np.mean([
                    len(set(np.asarray(ia[b, h]).tolist())
                        & set(np.asarray(ie[b, h]).tolist())) / budget
                    for b in range(B) for h in range(Hkv)])
                recalls[m].append(rec)
                # same recall/coverage definition the ONLINE audit plane
                # samples in production (DESIGN.md §10) — the offline
                # table and the serving telemetry must agree on what
                # "retrieval quality" means
                from repro.core.attention import sikv_static_audit_metrics
                am = sikv_static_audit_metrics(q, cache, cfg, topk=budget)
                audits.append((float(jnp.mean(am["recall"])),
                               float(jnp.mean(am["coverage"]))))
    for m in METHODS:
        mse = float(np.mean(errs[m]))
        extra = f"output_mse={mse:.5f}"
        if recalls[m]:
            extra += f";recall@{budget}={np.mean(recalls[m]):.3f}"
        if m == "sikv" and audits:
            extra += (f";audit_recall={np.mean([a[0] for a in audits]):.3f}"
                      f";audit_coverage={np.mean([a[1] for a in audits]):.3f}")
        emit(f"longbench_proxy/{m}", 0.0, extra)
    # ordering claim from Table 1 under query drift: self-indexing
    # *selection* (sikv16 isolates it from payload quantization, matching
    # the paper's "Ours (16 bits)" row) beats static pruning
    assert np.mean(errs["sikv16"]) <= np.mean(errs["snapkv"]) + 1e-6, (
        "SIKV-16bit selection should beat SnapKV at equal budget")
